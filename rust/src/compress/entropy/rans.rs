//! From-scratch **2-way interleaved rANS** coder over quantization codes
//! — the asymmetric-numeral-system sibling of the canonical Huffman stage
//! (in the spirit of orz's entropy backend), built for the skewed,
//! near-geometric code distributions the gradient-aware predictor emits.
//!
//! Invariants (see DESIGN.md §7):
//!
//! * **Static table**: per-stream symbol frequencies normalized to sum
//!   exactly [`SCALE`] (= 1 << 12), every present symbol keeping
//!   frequency ≥ 1, so sub-bit code lengths are representable where
//!   Huffman must spend a whole bit.
//! * **32-bit state, byte renormalization**: each lane's state `x` stays
//!   in `[RANS_L, 256 · RANS_L)`; the encoder emits low bytes while
//!   `x ≥ ((RANS_L >> SCALE_BITS) << 8) · freq`, the decoder refills
//!   while `x < RANS_L`. All arithmetic fits u32 (checked in tests).
//! * **2-way interleave**: symbol `i` goes to lane `i & 1`. The encoder
//!   walks the stream backwards pushing bytes into a scratch buffer that
//!   is reversed once at the end; the decoder walks forwards, so its
//!   byte reads replay the encoder's pushes in exact reverse order and
//!   the two lanes can share one byte stream. Lane 1 is flushed before
//!   lane 0 (LSB-first), so after the reversal the stream opens with
//!   lane 0's state big-endian, then lane 1's.
//! * Decoding must return both lanes to exactly [`RANS_L`] — a free
//!   integrity check on the whole stream.
//!
//! Serialized form (mode byte [`MODE_RANS`] keeps it distinguishable
//! from the Huffman stream's 0 = raw / 1 = huffman modes):
//!
//! ```text
//! u8 mode=2 | u32 count | u32 n_syms | n_syms × (i32 sym, u16 freq)
//!           | u32 stream_len | stream
//! ```

use crate::compress::quant::{code_histogram, FAST_RADIUS};
use std::collections::HashMap;

/// log2 of the frequency-normalization total.
pub const SCALE_BITS: u32 = 12;
/// Normalized frequencies sum to exactly this.
pub const SCALE: u32 = 1 << SCALE_BITS;
/// Lower bound of the normalized state interval.
pub const RANS_L: u32 = 1 << 23;
/// Alphabets larger than this cannot be normalized (each symbol needs
/// frequency ≥ 1); the caller falls back to Huffman/raw.
pub const MAX_SYMS: usize = SCALE as usize;
/// Leading mode byte of a serialized rANS stream.
pub const MODE_RANS: u8 = 2;

/// Normalize histogram counts to sum exactly [`SCALE`], each ≥ 1.
/// Requires `hist.len() <= MAX_SYMS` and a nonzero total.
fn normalize_freqs(hist: &[(i32, u64)], total: u64) -> Vec<u32> {
    let k = hist.len();
    debug_assert!(k >= 1 && k <= MAX_SYMS && total > 0);
    let mut freqs: Vec<u32> = hist
        .iter()
        .map(|&(_, c)| ((c as u128 * SCALE as u128 / total as u128) as u32).max(1))
        .collect();
    let mut sum: i64 = freqs.iter().map(|&f| f as i64).sum();
    if sum != SCALE as i64 {
        // Settle the rounding drift on the most frequent symbols, where a
        // ±1 slot costs the least precision. Cycling the index list
        // terminates: while sum > SCALE (≥ k), some frequency exceeds 1.
        let mut idx: Vec<usize> = (0..k).collect();
        idx.sort_by(|&a, &b| hist[b].1.cmp(&hist[a].1).then(a.cmp(&b)));
        let mut i = 0usize;
        while sum > SCALE as i64 {
            let j = idx[i % k];
            if freqs[j] > 1 {
                freqs[j] -= 1;
                sum -= 1;
            }
            i += 1;
        }
        let mut i = 0usize;
        while sum < SCALE as i64 {
            freqs[idx[i % k]] += 1;
            sum += 1;
            i += 1;
        }
    }
    freqs
}

/// Encode a code stream against its own histogram (as produced by
/// [`code_histogram`] **from these same codes** — a mismatched histogram
/// panics, which is why this stays crate-internal). Returns `None` when
/// rANS cannot apply (empty stream or alphabet too large for the
/// normalization).
pub(crate) fn encode_with_hist(codes: &[i32], hist: &[(i32, u64)]) -> Option<Vec<u8>> {
    let n_syms = hist.len();
    if codes.is_empty() || n_syms == 0 || n_syms > MAX_SYMS {
        return None;
    }
    let total: u64 = hist.iter().map(|&(_, c)| c).sum();
    let freqs = normalize_freqs(hist, total);
    let mut starts = vec![0u32; n_syms];
    let mut acc = 0u32;
    for (i, &f) in freqs.iter().enumerate() {
        starts[i] = acc;
        acc += f;
    }
    // Symbol -> table-index lookup: flat array fast path + HashMap overflow.
    let flat_len = (2 * FAST_RADIUS + 1) as usize;
    let mut flat_idx = vec![u32::MAX; flat_len];
    let mut overflow: HashMap<i32, u32> = HashMap::new();
    for (i, &(sym, _)) in hist.iter().enumerate() {
        if (-FAST_RADIUS..=FAST_RADIUS).contains(&sym) {
            flat_idx[(sym + FAST_RADIUS) as usize] = i as u32;
        } else {
            overflow.insert(sym, i as u32);
        }
    }
    // Backward pass: lane i&1, bytes pushed LSB-first then globally
    // reversed (see module docs).
    let mut x0: u32 = RANS_L;
    let mut x1: u32 = RANS_L;
    let mut rev: Vec<u8> = Vec::with_capacity(codes.len() / 2 + 16);
    for i in (0..codes.len()).rev() {
        let c = codes[i];
        let si = if (-FAST_RADIUS..=FAST_RADIUS).contains(&c) {
            flat_idx[(c + FAST_RADIUS) as usize] as usize
        } else {
            overflow[&c] as usize
        };
        let f = freqs[si];
        let x = if i & 1 == 0 { &mut x0 } else { &mut x1 };
        let x_max = ((RANS_L >> SCALE_BITS) << 8) * f;
        while *x >= x_max {
            rev.push(*x as u8);
            *x >>= 8;
        }
        *x = ((*x / f) << SCALE_BITS) + (*x % f) + starts[si];
    }
    for x in [x1, x0] {
        rev.push(x as u8);
        rev.push((x >> 8) as u8);
        rev.push((x >> 16) as u8);
        rev.push((x >> 24) as u8);
    }
    rev.reverse();
    let mut out = Vec::with_capacity(1 + 12 + n_syms * 6 + rev.len());
    out.push(MODE_RANS);
    out.extend_from_slice(&(codes.len() as u32).to_le_bytes());
    out.extend_from_slice(&(n_syms as u32).to_le_bytes());
    for (i, &(sym, _)) in hist.iter().enumerate() {
        out.extend_from_slice(&sym.to_le_bytes());
        out.extend_from_slice(&(freqs[i] as u16).to_le_bytes());
    }
    out.extend_from_slice(&(rev.len() as u32).to_le_bytes());
    out.extend_from_slice(&rev);
    Some(out)
}

/// Encode straight from codes (histogram computed internally).
pub fn encode_to_bytes(codes: &[i32]) -> Option<Vec<u8>> {
    encode_with_hist(codes, &code_histogram(codes))
}

/// Decode a serialized rANS stream, returning (codes, bytes consumed).
///
/// Unbounded form for callers decoding their own encodings; untrusted
/// streams should go through [`decode_bounded`] — a full-`SCALE`
/// single-symbol table decodes symbols without consuming stream bytes,
/// so `count` alone must not size the output.
pub fn decode_from_bytes(buf: &[u8]) -> anyhow::Result<(Vec<i32>, usize)> {
    decode_bounded(buf, u32::MAX as usize)
}

/// [`decode_from_bytes`] with a caller-known cap on the symbol count
/// (e.g. the layer's `numel` from the already-parsed blob header).
/// Streams declaring more symbols are rejected before any work.
pub fn decode_bounded(buf: &[u8], max_count: usize) -> anyhow::Result<(Vec<i32>, usize)> {
    use anyhow::bail;
    if buf.first() != Some(&MODE_RANS) {
        bail!("not a rANS stream");
    }
    let mut pos = 1usize;
    let rd_u32 = |buf: &[u8], pos: &mut usize| -> anyhow::Result<u32> {
        if *pos + 4 > buf.len() {
            anyhow::bail!("truncated rANS stream");
        }
        let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
        *pos += 4;
        Ok(v)
    };
    let count = rd_u32(buf, &mut pos)? as usize;
    if count > max_count {
        bail!("rANS stream declares {count} symbols, expected at most {max_count}");
    }
    let n_syms = rd_u32(buf, &mut pos)? as usize;
    if n_syms == 0 || n_syms > MAX_SYMS {
        bail!("rANS alphabet size {n_syms} out of range");
    }
    if pos + n_syms * 6 > buf.len() {
        bail!("truncated rANS table");
    }
    let mut syms = Vec::with_capacity(n_syms);
    let mut freqs = Vec::with_capacity(n_syms);
    let mut sum = 0u32;
    for _ in 0..n_syms {
        let sym = i32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap());
        let f = u16::from_le_bytes(buf[pos + 4..pos + 6].try_into().unwrap()) as u32;
        pos += 6;
        if f == 0 {
            bail!("rANS table has zero frequency");
        }
        syms.push(sym);
        freqs.push(f);
        sum += f;
    }
    if sum != SCALE {
        bail!("rANS frequencies sum to {sum}, expected {SCALE}");
    }
    let stream_len = rd_u32(buf, &mut pos)? as usize;
    if pos + stream_len > buf.len() {
        bail!("truncated rANS payload");
    }
    let stream = &buf[pos..pos + stream_len];
    pos += stream_len;
    if count == 0 {
        return Ok((Vec::new(), pos));
    }
    if stream_len < 8 {
        bail!("rANS payload shorter than the state flush");
    }
    // slot -> table index, plus per-symbol interval starts.
    let mut starts = vec![0u32; n_syms];
    let mut slot_sym = vec![0u16; SCALE as usize];
    let mut acc = 0u32;
    for (i, &f) in freqs.iter().enumerate() {
        starts[i] = acc;
        for s in slot_sym.iter_mut().skip(acc as usize).take(f as usize) {
            *s = i as u16;
        }
        acc += f;
    }
    let mut x0 = u32::from_be_bytes(stream[0..4].try_into().unwrap());
    let mut x1 = u32::from_be_bytes(stream[4..8].try_into().unwrap());
    let mut sp = 8usize;
    let mut out = Vec::with_capacity(count.min(1 << 22));
    for i in 0..count {
        let x = if i & 1 == 0 { &mut x0 } else { &mut x1 };
        let slot = *x & (SCALE - 1);
        let si = slot_sym[slot as usize] as usize;
        out.push(syms[si]);
        // u64 intermediate: corrupt initial states could otherwise
        // overflow the u32 multiply; valid states never do.
        let nx = freqs[si] as u64 * (*x >> SCALE_BITS) as u64 + (slot - starts[si]) as u64;
        *x = nx as u32;
        while *x < RANS_L {
            if sp >= stream.len() {
                bail!("rANS stream underrun at symbol {i}");
            }
            *x = (*x << 8) | stream[sp] as u32;
            sp += 1;
        }
    }
    if x0 != RANS_L || x1 != RANS_L {
        bail!("rANS final-state mismatch (corrupt stream)");
    }
    Ok((out, pos))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quant::ESCAPE_CODE;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn roundtrip(codes: &[i32]) -> Vec<u8> {
        let bytes = encode_to_bytes(codes).expect("encodable");
        let (got, used) = decode_from_bytes(&bytes).expect("decodable");
        assert_eq!(got, codes);
        assert_eq!(used, bytes.len());
        bytes
    }

    #[test]
    fn golden_single_symbol_stream_is_frozen() {
        // [7, 7, 7, 7]: one symbol at frequency SCALE, both lanes park at
        // RANS_L untouched — the stream is exactly the two flushed states.
        let bytes = encode_to_bytes(&[7, 7, 7, 7]).unwrap();
        #[rustfmt::skip]
        let expect: Vec<u8> = vec![
            2,              // MODE_RANS
            4, 0, 0, 0,     // count
            1, 0, 0, 0,     // n_syms
            7, 0, 0, 0,     // symbol 7
            0, 16,          // freq 4096
            8, 0, 0, 0,     // stream length
            0, 128, 0, 0,   // lane 0 state, big-endian RANS_L
            0, 128, 0, 0,   // lane 1 state
        ];
        assert_eq!(bytes, expect);
        let (got, used) = decode_from_bytes(&bytes).unwrap();
        assert_eq!(got, vec![7, 7, 7, 7]);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn roundtrip_adversarial_distributions() {
        let mut rng = Rng::new(7);
        // Single symbol.
        let single = vec![-3; 4097];
        roundtrip(&single);
        // Uniform over a power-of-two alphabet (Huffman's best case).
        let uniform: Vec<i32> = (0..8192).map(|i| i % 16).collect();
        roundtrip(&uniform);
        // Geometric (the predictor's typical residual shape).
        let geo: Vec<i32> = (0..20_000)
            .map(|_| {
                let mut v = 0i32;
                while rng.chance(0.6) {
                    v += 1;
                }
                if rng.chance(0.5) {
                    -v
                } else {
                    v
                }
            })
            .collect();
        let enc = roundtrip(&geo);
        assert!(enc.len() < geo.len(), "geometric should beat 1 byte/sym");
        // Escape-heavy: the ESCAPE_CODE marker mixed through.
        let esc: Vec<i32> = (0..5000)
            .map(|i| if i % 3 == 0 { ESCAPE_CODE } else { (i % 7) as i32 - 3 })
            .collect();
        roundtrip(&esc);
        // Odd lengths exercise the interleave parity.
        roundtrip(&[5]);
        roundtrip(&[5, -5, 5]);
    }

    #[test]
    fn empty_and_oversized_alphabets_decline() {
        assert!(encode_to_bytes(&[]).is_none());
        let wide: Vec<i32> = (0..(MAX_SYMS as i32 + 1)).collect();
        assert!(encode_to_bytes(&wide).is_none());
        let exactly: Vec<i32> = (0..(MAX_SYMS as i32)).collect();
        roundtrip(&exactly);
    }

    #[test]
    fn normalization_sums_to_scale() {
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let k = 1 + rng.next_below(300);
            let hist: Vec<(i32, u64)> =
                (0..k).map(|i| (i as i32, 1 + rng.next_below(100_000) as u64)).collect();
            let total: u64 = hist.iter().map(|&(_, c)| c).sum();
            let freqs = normalize_freqs(&hist, total);
            assert_eq!(freqs.iter().map(|&f| f as u64).sum::<u64>(), SCALE as u64);
            assert!(freqs.iter().all(|&f| f >= 1));
        }
    }

    #[test]
    fn bounded_decode_rejects_inflated_count() {
        // A flipped count high byte on a single-symbol stream would
        // otherwise decode ~4e9 symbols without consuming a byte (the
        // lanes never renorm at freq == SCALE) — the bound must catch it.
        let mut bytes = encode_to_bytes(&[7, 7, 7, 7]).unwrap();
        assert!(decode_bounded(&bytes, 4).is_ok());
        assert!(decode_bounded(&bytes, 3).is_err());
        bytes[4] = 0xFF; // count = 4 | 0xFF000000
        assert!(decode_bounded(&bytes, 1 << 20).is_err());
    }

    #[test]
    fn corrupt_streams_error_not_panic() {
        let bytes = encode_to_bytes(&[1, 2, 3, 1, 2, 1, 1, 1, 0, 0, 0]).unwrap();
        assert!(decode_from_bytes(&bytes[..bytes.len() - 3]).is_err());
        assert!(decode_from_bytes(&[]).is_err());
        assert!(decode_from_bytes(&[MODE_RANS]).is_err());
        for i in 1..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0xFF;
            // Any outcome but a panic is acceptable; most flips are caught
            // by the table checks or the final-state invariant.
            let _ = decode_from_bytes(&bad);
        }
    }

    #[test]
    fn property_roundtrip_random_streams() {
        prop::check("rans roundtrip", 100, |rng| {
            let n = prop::arb_len(rng, 5000);
            let spread = 1 + rng.next_below(1000) as i32;
            let codes: Vec<i32> =
                (0..n).map(|_| rng.next_below(spread as usize * 2) as i32 - spread).collect();
            let bytes = encode_to_bytes(&codes).ok_or("declined")?;
            let (got, used) = decode_from_bytes(&bytes).map_err(|e| e.to_string())?;
            if got != codes {
                return Err("mismatch".into());
            }
            if used != bytes.len() {
                return Err(format!("used {used} != len {}", bytes.len()));
            }
            Ok(())
        });
    }
}
