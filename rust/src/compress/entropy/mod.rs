//! Stage 3 front door: the **entropy-coder registry** closing the
//! pipeline after quantization. One enum selects between the canonical
//! Huffman coder ([`super::huffman`]), the N-way interleaved rANS coders
//! ([`rans`] — 2-way legacy plus 4/8-way ILP twins) and a raw i32 store,
//! per codec via the `CodecSpec` grammar
//! (`ec=huff|rans|rans4|rans8|raw`, Huffman the byte-compatible default).
//!
//! Every serialized entropy stream is self-describing through its
//! leading mode byte (0 = raw, 1 = huffman, 2 = rans 2-way, 3 = rans
//! 4-way, 4 = rans 8-way), and the layer blob additionally records the
//! *selected* coder tag (see [`super::blob`]) so the decoder dispatches
//! without sniffing. Each rANS path is chosen **by measured size**: it
//! computes the exact Huffman and raw alternatives from the shared
//! histogram and only emits the rANS stream when it is no larger — so
//! `ec=rans*` never loses a byte to `ec=huff` on any layer (the Table 4b
//! panel asserts this). The lane widths are distinct wire formats; any
//! `Rans*` coder decodes any of them (the mode byte picks the
//! interleave), so old 2-way frames decode unchanged under the new
//! coders.

pub mod rans;

use super::huffman;
use crate::compress::quant::code_histogram;

/// Which stage-3 coder closes a layer's residual stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EntropyCoder {
    /// Canonical Huffman (the seed coder; byte-compatible default).
    #[default]
    Huffman,
    /// 2-way interleaved rANS with size-based Huffman/raw fallback.
    Rans,
    /// 4-way interleaved rANS — same table, wider ILP interleave.
    Rans4,
    /// 8-way interleaved rANS — widest interleave.
    Rans8,
    /// Raw little-endian i32 store (ablation / debugging).
    Raw,
}

impl EntropyCoder {
    /// All coders, for registry-style sweeps.
    pub const ALL: [EntropyCoder; 5] = [
        EntropyCoder::Huffman,
        EntropyCoder::Rans,
        EntropyCoder::Rans4,
        EntropyCoder::Rans8,
        EntropyCoder::Raw,
    ];

    /// Spec-grammar name (`ec=<name>`).
    pub fn name(&self) -> &'static str {
        match self {
            EntropyCoder::Huffman => "huff",
            EntropyCoder::Rans => "rans",
            EntropyCoder::Rans4 => "rans4",
            EntropyCoder::Rans8 => "rans8",
            EntropyCoder::Raw => "raw",
        }
    }

    /// Parse a spec-grammar name.
    pub fn from_name(s: &str) -> Option<EntropyCoder> {
        match s {
            "huff" | "huffman" => Some(EntropyCoder::Huffman),
            "rans" => Some(EntropyCoder::Rans),
            "rans4" => Some(EntropyCoder::Rans4),
            "rans8" => Some(EntropyCoder::Rans8),
            "raw" => Some(EntropyCoder::Raw),
            _ => None,
        }
    }

    /// Coder tag recorded in v2 layer blobs ([`super::blob`]).
    pub fn tag(&self) -> u8 {
        match self {
            EntropyCoder::Huffman => 0,
            EntropyCoder::Rans => 1,
            EntropyCoder::Raw => 2,
            EntropyCoder::Rans4 => 3,
            EntropyCoder::Rans8 => 4,
        }
    }

    /// Inverse of [`Self::tag`].
    pub fn from_tag(t: u8) -> anyhow::Result<EntropyCoder> {
        match t {
            0 => Ok(EntropyCoder::Huffman),
            1 => Ok(EntropyCoder::Rans),
            2 => Ok(EntropyCoder::Raw),
            3 => Ok(EntropyCoder::Rans4),
            4 => Ok(EntropyCoder::Rans8),
            _ => anyhow::bail!("unknown entropy-coder tag {t}"),
        }
    }

    /// rANS interleave width this coder emits, `None` for non-rANS.
    pub fn rans_lanes(&self) -> Option<usize> {
        match self {
            EntropyCoder::Rans => Some(2),
            EntropyCoder::Rans4 => Some(4),
            EntropyCoder::Rans8 => Some(8),
            _ => None,
        }
    }

    /// Encode a code stream to its serialized, self-describing form.
    pub fn encode_to_bytes(&self, codes: &[i32]) -> Vec<u8> {
        match self {
            // Raw never looks at frequencies; skip the histogram pass.
            EntropyCoder::Raw => self.encode_to_bytes_with_hist(codes, &[]),
            _ => self.encode_to_bytes_with_hist(codes, &code_histogram(codes)),
        }
    }

    /// [`Self::encode_to_bytes`] against a precomputed histogram of these
    /// same codes — the pipeline histograms each layer once and shares it
    /// between the autotuner's coder choice and the chosen encoder.
    /// Crate-internal: a histogram not derived from `codes` panics.
    pub(crate) fn encode_to_bytes_with_hist(
        &self,
        codes: &[i32],
        hist: &[(i32, u64)],
    ) -> Vec<u8> {
        let raw = |codes: &[i32]| {
            let mut out = Vec::with_capacity(5 + codes.len() * 4);
            huffman::Encoded::Raw(codes.to_vec()).write_to(&mut out);
            out
        };
        if codes.is_empty() {
            return raw(codes);
        }
        let huffman_bytes = |codes: &[i32], hist: &[(i32, u64)]| {
            let enc = huffman::encode_with_hist(codes, hist);
            let mut out = Vec::with_capacity(enc.byte_size());
            enc.write_to(&mut out);
            out
        };
        let out = match self {
            EntropyCoder::Huffman => huffman_bytes(codes, hist),
            EntropyCoder::Raw => raw(codes),
            EntropyCoder::Rans | EntropyCoder::Rans4 | EntropyCoder::Rans8 => {
                let lanes = self.rans_lanes().expect("rans coder");
                let raw_size = 1 + 4 + codes.len() * 4;
                let huff_size = huffman::serialized_size_from_hist(hist).unwrap_or(usize::MAX);
                match rans::encode_with_hist_lanes(codes, hist, lanes) {
                    Some(r) if r.len() <= huff_size && r.len() < raw_size => r,
                    // Huffman (or its own raw fallback) measured smaller.
                    _ => huffman_bytes(codes, hist),
                }
            }
        };
        // Tally by the mode byte actually written, not the requested
        // coder — fallbacks land in the bucket the wire will show.
        let tally = match out.first() {
            Some(&rans::MODE_RANS) => &crate::telemetry::ENTROPY_RANS_BYTES,
            Some(&rans::MODE_RANS4) => &crate::telemetry::ENTROPY_RANS4_BYTES,
            Some(&rans::MODE_RANS8) => &crate::telemetry::ENTROPY_RANS8_BYTES,
            Some(1) => &crate::telemetry::ENTROPY_HUFF_BYTES,
            _ => &crate::telemetry::ENTROPY_RAW_BYTES,
        };
        tally.add(out.len() as u64);
        out
    }

    /// Decode a stream this coder produced, returning (codes, bytes
    /// consumed). Unbounded form for callers decoding their own
    /// encodings; untrusted streams go through [`Self::decode_bounded`].
    pub fn decode_from_bytes(&self, buf: &[u8]) -> anyhow::Result<(Vec<i32>, usize)> {
        self.decode_bounded(buf, u32::MAX as usize)
    }

    /// [`Self::decode_from_bytes`] with a caller-known cap on the symbol
    /// count (the layer's `numel`, parsed from the blob header before the
    /// entropy bytes). Both the rANS and Huffman stream formats can
    /// declare far more symbols than they carry bits for (symbols can
    /// cost < 1 bit / 0 bits), so the declared count is validated before
    /// any decode work — the decompressors' untrusted-payload guard.
    /// The dispatch is driven by the coder recorded in the layer blob;
    /// each coder accepts only the modes it can emit. Any `Rans*` coder
    /// accepts any rANS lane width — the mode byte selects the
    /// interleave, which is how pre-widening 2-way frames stay decodable
    /// under the wider coders.
    pub fn decode_bounded(
        &self,
        buf: &[u8],
        max_count: usize,
    ) -> anyhow::Result<(Vec<i32>, usize)> {
        let mode = *buf
            .first()
            .ok_or_else(|| anyhow::anyhow!("empty entropy stream"))?;
        let bounded_huffman = |buf: &[u8]| -> anyhow::Result<(Vec<i32>, usize)> {
            let declared = huffman::Encoded::declared_count(buf)? as usize;
            anyhow::ensure!(
                declared <= max_count,
                "entropy stream declares {declared} symbols, expected at most {max_count}"
            );
            huffman::decode_from_bytes(buf)
        };
        let is_rans = self.rans_lanes().is_some();
        match mode {
            rans::MODE_RANS | rans::MODE_RANS4 | rans::MODE_RANS8 if is_rans => {
                rans::decode_bounded(buf, max_count)
            }
            // The rANS selector may have fallen back to huffman/raw.
            0 | 1 if is_rans || *self == EntropyCoder::Huffman => bounded_huffman(buf),
            0 if *self == EntropyCoder::Raw => bounded_huffman(buf),
            m => {
                anyhow::bail!("entropy stream mode {m} inconsistent with coder '{}'", self.name())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quant::ESCAPE_CODE;
    use crate::util::rng::Rng;

    /// The adversarial alphabet shapes every coder must survive, and on
    /// which rANS must never emit more bytes than Huffman.
    fn adversarial_streams() -> Vec<(&'static str, Vec<i32>)> {
        let mut rng = Rng::new(0xEC);
        let geometric: Vec<i32> = (0..30_000)
            .map(|_| {
                let mut v = 0i32;
                while rng.chance(0.7) {
                    v += 1;
                }
                if rng.chance(0.5) {
                    -v
                } else {
                    v
                }
            })
            .collect();
        let escape_heavy: Vec<i32> = (0..8000)
            .map(|i| if i % 2 == 0 { ESCAPE_CODE } else { (i % 5) as i32 })
            .collect();
        vec![
            ("single", vec![42; 10_000]),
            ("uniform-pow2", (0..16_384).map(|i| i % 32).collect()),
            ("geometric", geometric),
            ("escape-heavy", escape_heavy),
            ("tiny", vec![1, -1, 0]),
            ("empty", Vec::new()),
        ]
    }

    #[test]
    fn all_coders_roundtrip_adversarial_streams() {
        for (name, codes) in adversarial_streams() {
            for coder in EntropyCoder::ALL {
                let bytes = coder.encode_to_bytes(&codes);
                let (got, used) = coder
                    .decode_from_bytes(&bytes)
                    .unwrap_or_else(|e| panic!("{name}/{}: {e}", coder.name()));
                assert_eq!(got, codes, "{name}/{}", coder.name());
                assert_eq!(used, bytes.len(), "{name}/{}", coder.name());
            }
        }
    }

    #[test]
    fn rans_and_huffman_decode_identical_codes() {
        // The drop-in invariant: every rANS width and Huffman decode to
        // identical codes on every shape, and no rANS width ever emits
        // more bytes than Huffman (the size race guarantees it).
        for (name, codes) in adversarial_streams() {
            let h = EntropyCoder::Huffman.encode_to_bytes(&codes);
            let (hd, _) = EntropyCoder::Huffman.decode_from_bytes(&h).unwrap();
            for coder in [EntropyCoder::Rans, EntropyCoder::Rans4, EntropyCoder::Rans8] {
                let r = coder.encode_to_bytes(&codes);
                let (rd, _) = coder.decode_from_bytes(&r).unwrap();
                assert_eq!(hd, rd, "{name}/{}", coder.name());
                assert!(
                    r.len() <= h.len(),
                    "{name}/{}: rans {} bytes > huffman {} bytes",
                    coder.name(),
                    r.len(),
                    h.len()
                );
            }
        }
    }

    #[test]
    fn wider_lanes_decode_legacy_two_way_frames() {
        // The back-compat contract: a stream encoded by the legacy 2-way
        // coder decodes unchanged under the rans4/rans8 registry twins
        // (the mode byte picks the interleave, not the coder).
        let codes: Vec<i32> = (0..5000).map(|i| (i % 9) as i32 - 4).collect();
        let legacy = EntropyCoder::Rans.encode_to_bytes(&codes);
        assert_eq!(legacy[0], rans::MODE_RANS);
        for coder in [EntropyCoder::Rans4, EntropyCoder::Rans8] {
            let (got, used) = coder.decode_from_bytes(&legacy).unwrap();
            assert_eq!(got, codes, "{}", coder.name());
            assert_eq!(used, legacy.len());
        }
        // And the reverse: the legacy coder decodes wide-lane streams.
        let wide = EntropyCoder::Rans8.encode_to_bytes(&codes);
        assert_eq!(wide[0], rans::MODE_RANS8);
        let (got, _) = EntropyCoder::Rans.decode_from_bytes(&wide).unwrap();
        assert_eq!(got, codes);
    }

    #[test]
    fn rans_beats_huffman_on_skewed_streams() {
        // Heavily skewed two-symbol stream: entropy ≈ 0.29 bit/sym while
        // Huffman is stuck at 1 bit/sym — rANS must win outright.
        let mut rng = Rng::new(3);
        let codes: Vec<i32> =
            (0..50_000).map(|_| if rng.chance(0.95) { 0 } else { 1 }).collect();
        let h = EntropyCoder::Huffman.encode_to_bytes(&codes);
        let r = EntropyCoder::Rans.encode_to_bytes(&codes);
        assert_eq!(r[0], rans::MODE_RANS, "selector must pick the rANS stream");
        assert!(
            (r.len() as f64) < 0.6 * h.len() as f64,
            "rans {} vs huffman {}",
            r.len(),
            h.len()
        );
    }

    #[test]
    fn coder_tags_and_names_roundtrip() {
        for c in EntropyCoder::ALL {
            assert_eq!(EntropyCoder::from_tag(c.tag()).unwrap(), c);
            assert_eq!(EntropyCoder::from_name(c.name()), Some(c));
        }
        assert!(EntropyCoder::from_tag(9).is_err());
        assert_eq!(EntropyCoder::from_name("bogus"), None);
        assert_eq!(EntropyCoder::default(), EntropyCoder::Huffman);
        // The wire tags of the frozen coders must never move.
        assert_eq!(EntropyCoder::Huffman.tag(), 0);
        assert_eq!(EntropyCoder::Rans.tag(), 1);
        assert_eq!(EntropyCoder::Raw.tag(), 2);
        assert_eq!(EntropyCoder::Rans4.tag(), 3);
        assert_eq!(EntropyCoder::Rans8.tag(), 4);
    }

    #[test]
    fn mode_mismatch_is_rejected() {
        let codes = vec![1, 2, 3, 1, 2, 1];
        let r = rans::encode_to_bytes(&codes).unwrap();
        // A legacy-huffman layer must not carry a rANS stream.
        assert!(EntropyCoder::Huffman.decode_from_bytes(&r).is_err());
        let repeated = vec![5; 100];
        let h = EntropyCoder::Huffman.encode_to_bytes(&repeated);
        assert!(EntropyCoder::Raw.decode_from_bytes(&h).is_err());
    }
}
