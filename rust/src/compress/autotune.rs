//! Auto-tuning of the compressor's hyper-parameters — the paper's §6
//! future-work item ("develop auto-tuning mechanisms that can dynamically
//! adapt these parameters based on the observed gradient statistics
//! during training"), implemented here as a first-class feature.
//!
//! Two knobs with two different synchronization constraints:
//!
//! * **τ (sign-consistency threshold)** is a *client-only* decision: the
//!   two-level bitmap already tells the server exactly which kernels were
//!   predicted, so the client may move τ freely with zero extra
//!   communication. We adapt it with a proportional controller targeting
//!   the sign-mismatch rate: too many mismatches ⇒ raise τ (predict only
//!   very consistent kernels); few mismatches and low coverage ⇒ lower τ.
//!
//! * **β (EMA decay)** feeds the server-side magnitude predictor, so both
//!   sides must use the same value every round. We therefore derive β
//!   deterministically from *reconstructed* history only (the temporal
//!   autocorrelation of the previous reconstructed magnitudes), which
//!   both sides hold bit-identically — no side channel needed.

use super::entropy::{rans, EntropyCoder};
use super::huffman;
use crate::compress::quant::code_histogram;
use crate::util::stats;

/// Choose the cheaper stage-3 coder for one layer's code stream — the
/// third client-only knob (like τ, the choice is recorded in the layer
/// blob, so the server follows with zero extra communication).
///
/// The decision arbitrates only between Huffman and rANS by comparing
/// the **exact** Huffman serialized size (derived from the histogram
/// without emitting bits) against the rANS size estimate (Shannon
/// payload bound + table + state flush). Tiny streams keep `default`
/// (the table overhead dominates and the coders' own raw fallback
/// already guards the floor), and `ec=raw` is an explicit ablation
/// choice the autotuner never overrides.
pub fn pick_entropy_coder(codes: &[i32], default: EntropyCoder) -> EntropyCoder {
    if default == EntropyCoder::Raw || codes.len() < 256 {
        return default;
    }
    pick_entropy_coder_from_hist(&code_histogram(codes), codes.len(), default)
}

/// [`pick_entropy_coder`] against a precomputed histogram of the same
/// `n_codes`-long stream (the pipeline shares one histogram between the
/// choice and the chosen encoder).
pub fn pick_entropy_coder_from_hist(
    hist: &[(i32, u64)],
    n_codes: usize,
    default: EntropyCoder,
) -> EntropyCoder {
    if default == EntropyCoder::Raw || n_codes < 256 {
        return default;
    }
    if hist.len() > rans::MAX_SYMS {
        return EntropyCoder::Huffman;
    }
    // A wider-lane default keeps its lane width: the chooser arbitrates
    // Huffman vs rANS, not 2-way vs 4/8-way (that is the caller's
    // throughput/ratio trade to make).
    let (rans_pick, lanes) = match default.rans_lanes() {
        Some(n) => (default, n),
        None => (EntropyCoder::Rans, 2),
    };
    let n = n_codes as f64;
    let mut shannon_bits = 0.0f64;
    for &(_, c) in hist {
        let p = c as f64 / n;
        shannon_bits -= c as f64 * p.log2();
    }
    let huff_bytes = match huffman::serialized_size_from_hist(hist) {
        Some(s) => s as f64,
        None => return rans_pick,
    };
    let rans_bytes = shannon_bits / 8.0 + (6 * hist.len() + 4 * lanes + 13) as f64;
    if rans_bytes < huff_bytes {
        rans_pick
    } else {
        EntropyCoder::Huffman
    }
}

/// Exact size in bytes of the Huffman-coder stage-3 stream for a code
/// stream with this histogram (the coder's own raw fallback included) —
/// the `pred=auto` race metric, extending the same exact-size machinery
/// the entropy-coder chooser uses. Like τ and the coder choice, the
/// race is a **client-only** decision: the winner's tag is recorded in
/// the layer blob, so the server follows with zero synchronization
/// cost. Exact for `ec=huff` without autotune; for `ec=rans` (whose
/// size-checked selector never emits more than this) it is a
/// size-faithful upper bound, so a race winner under this metric never
/// loses actual bytes.
pub fn entropy_stage_cost(hist: &[(i32, u64)], n_codes: usize) -> usize {
    let raw = 1 + 4 + n_codes * 4;
    match huffman::serialized_size_from_hist(hist) {
        Some(s) => s.min(raw),
        None => raw,
    }
}

/// Controller for the client-side τ.
///
/// Ownership note for the externalized-state world: τ controllers are
/// **client-local** state, deliberately *not* part of the mirrored
/// [`super::state::CodecState`] (the bitmap already tells the server
/// which kernels were predicted), so they never enter the server's
/// `StateStore`, never count against its budget, and never appear in a
/// state fingerprint. A `StateResync` cold-start clears them with the
/// rest of `GradientCodec::reset`. β auto-tuning by contrast derives
/// deterministically from the mirrored `|g̃|` history on both sides.
#[derive(Debug, Clone)]
pub struct TauController {
    pub tau: f64,
    /// Target sign-mismatch rate among predicted elements (paper Table 5
    /// reports ~10% as the healthy operating point).
    pub target_mismatch: f64,
    /// Proportional gain.
    pub gain: f64,
    pub min_tau: f64,
    pub max_tau: f64,
}

impl Default for TauController {
    fn default() -> Self {
        TauController { tau: 0.5, target_mismatch: 0.10, gain: 0.5, min_tau: 0.1, max_tau: 0.95 }
    }
}

impl TauController {
    /// Update τ from the last round's observed mismatch rate and coverage.
    pub fn update(&mut self, mismatch_rate: f64, prediction_ratio: f64) {
        // Mismatch above target pushes τ up; well below target with thin
        // coverage pulls τ down to predict more kernels.
        let err = mismatch_rate - self.target_mismatch;
        let mut step = self.gain * err;
        if err < 0.0 && prediction_ratio > 0.9 {
            // Already predicting nearly everything cleanly: hold.
            step = 0.0;
        }
        self.tau = (self.tau + step).clamp(self.min_tau, self.max_tau);
    }
}

/// Deterministic β schedule from reconstructed magnitude history.
///
/// Given the previous two reconstructed |g| tensors (available on both
/// sides), β tracks their correlation: strongly persistent magnitudes ⇒
/// long memory (β→0.95); decorrelated ⇒ short memory (β→0.3).
pub fn beta_from_history(prev_abs: &[f32], prev_prev_abs: &[f32]) -> f32 {
    if prev_abs.is_empty() || prev_abs.len() != prev_prev_abs.len() {
        return 0.9;
    }
    let corr = stats::pearson(prev_abs, prev_prev_abs).clamp(0.0, 1.0);
    // Map corr in [0,1] -> beta in [0.3, 0.95].
    (0.3 + 0.65 * corr) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_rises_on_high_mismatch() {
        let mut c = TauController::default();
        let t0 = c.tau;
        c.update(0.4, 0.6);
        assert!(c.tau > t0);
    }

    #[test]
    fn tau_falls_on_clean_thin_coverage() {
        let mut c = TauController::default();
        let t0 = c.tau;
        c.update(0.0, 0.2);
        assert!(c.tau < t0);
    }

    #[test]
    fn tau_holds_when_saturated() {
        let mut c = TauController::default();
        let t0 = c.tau;
        c.update(0.01, 0.95);
        assert_eq!(c.tau, t0);
    }

    #[test]
    fn tau_stays_in_bounds() {
        let mut c = TauController::default();
        for _ in 0..100 {
            c.update(1.0, 0.5);
        }
        assert!(c.tau <= c.max_tau);
        for _ in 0..100 {
            c.update(0.0, 0.0);
        }
        assert!(c.tau >= c.min_tau);
    }

    #[test]
    fn beta_tracks_correlation() {
        let a: Vec<f32> = (0..100).map(|i| (i as f32 / 10.0).sin().abs()).collect();
        let high = beta_from_history(&a, &a);
        assert!(high > 0.9);
        let b: Vec<f32> = (0..100).map(|i| ((i * 7919) % 100) as f32 / 100.0).collect();
        let low = beta_from_history(&a, &b);
        assert!(low < high);
        assert_eq!(beta_from_history(&[], &[]), 0.9);
    }

    #[test]
    fn coder_choice_tracks_distribution_shape() {
        use crate::util::rng::Rng;
        // Tiny streams keep the configured default.
        assert_eq!(
            pick_entropy_coder(&[1, 2, 3], EntropyCoder::Raw),
            EntropyCoder::Raw
        );
        // Heavily skewed stream: sub-bit symbols favor rANS.
        let mut rng = Rng::new(4);
        let skewed: Vec<i32> =
            (0..20_000).map(|_| if rng.chance(0.97) { 0 } else { 1 }).collect();
        assert_eq!(
            pick_entropy_coder(&skewed, EntropyCoder::Huffman),
            EntropyCoder::Rans
        );
        // The choice is deterministic (client/server report symmetry).
        assert_eq!(
            pick_entropy_coder(&skewed, EntropyCoder::Huffman),
            pick_entropy_coder(&skewed, EntropyCoder::Huffman)
        );
        // ec=raw is an explicit ablation choice: never overridden.
        assert_eq!(pick_entropy_coder(&skewed, EntropyCoder::Raw), EntropyCoder::Raw);
        // Hist-threaded form agrees with the convenience wrapper.
        let hist = crate::compress::quant::code_histogram(&skewed);
        assert_eq!(
            pick_entropy_coder_from_hist(&hist, skewed.len(), EntropyCoder::Huffman),
            pick_entropy_coder(&skewed, EntropyCoder::Huffman)
        );
    }

    #[test]
    fn coder_choice_preserves_lane_width() {
        use crate::util::rng::Rng;
        // A wide-lane default that wins the size race keeps its width —
        // the chooser never silently downgrades rans4/rans8 to 2-way.
        let mut rng = Rng::new(9);
        let skewed: Vec<i32> =
            (0..20_000).map(|_| if rng.chance(0.97) { 0 } else { 1 }).collect();
        assert_eq!(pick_entropy_coder(&skewed, EntropyCoder::Rans4), EntropyCoder::Rans4);
        assert_eq!(pick_entropy_coder(&skewed, EntropyCoder::Rans8), EntropyCoder::Rans8);
    }

    #[test]
    fn entropy_stage_cost_matches_emitted_bytes() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xAB);
        // Skewed and near-uniform streams: the metric must equal the
        // Huffman coder's actual serialized size (raw fallback included).
        for p0 in [0.99, 0.6, 0.03] {
            let codes: Vec<i32> = (0..10_000)
                .map(|_| if rng.chance(p0) { 0 } else { (rng.next_below(40) as i32) - 20 })
                .collect();
            let hist = code_histogram(&codes);
            let emitted = EntropyCoder::Huffman.encode_to_bytes(&codes);
            assert_eq!(entropy_stage_cost(&hist, codes.len()), emitted.len(), "p0={p0}");
        }
        // The rANS selector never exceeds the metric.
        let codes: Vec<i32> = (0..20_000).map(|_| (rng.next_below(7) as i32) - 3).collect();
        let hist = code_histogram(&codes);
        let rans = EntropyCoder::Rans.encode_to_bytes(&codes);
        assert!(rans.len() <= entropy_stage_cost(&hist, codes.len()));
    }

    #[test]
    fn beta_is_deterministic_pure_function() {
        let a: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..64).map(|i| (i * 2) as f32).collect();
        assert_eq!(beta_from_history(&a, &b), beta_from_history(&a, &b));
    }
}
