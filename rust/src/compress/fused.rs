//! The fused predict+quantize hot path — one elementwise pass combining
//! magnitude prediction (Alg. 1), sign application, residual formation and
//! error-bounded quantization. This is the L1 compute hot-spot: the same
//! math is implemented by the Pallas kernel
//! (`python/compile/kernels/predict_quantize.py`), and the
//! `hlo_runtime` integration test asserts the two produce identical codes.
//!
//! All reductions (μ/σ of previous and current magnitudes) happen *before*
//! this pass and are passed in as scalars, so the pass is purely
//! elementwise — that is what makes the native and PJRT engines agree
//! bit-for-bit (DESIGN.md §1).
//!
//! The pass ships as a **twin pair** (DESIGN.md §12): a bounds-checked
//! scalar kernel and a [`kernels::CHUNK`]-wide chunked kernel with
//! unchecked indexing that the autovectorizer can turn into SIMD. Both
//! twins run the identical per-element f32 steps (`encode_step_pred`,
//! the shared `predict_mag`) in the identical order, so their output streams are
//! bit-identical by construction; the registry-wide property tests
//! assert it. The warm-state (`have_prev`) path is the hot one — round-1
//! (no prediction) and degenerate `2Δ ≤ 0` inputs always take the scalar
//! twin.

use super::kernels;
use super::kernels::CHUNK;
use super::predictor::magnitude::ema_norm_step;
use super::quant::{CODE_RADIUS, ESCAPE_CODE};

/// Scalar parameters of one fused pass.
#[derive(Debug, Clone, Copy)]
pub struct FusedParams {
    /// EMA decay β.
    pub beta: f32,
    /// Stats of the *current* absolute gradient (transmitted).
    pub mu_curr: f32,
    pub sigma_curr: f32,
    /// Stats of the *previous reconstructed* absolute gradient (recomputed
    /// identically on both sides).
    pub mu_prev: f32,
    pub sigma_prev: f32,
    /// Quantization bin width 2Δ and bound Δ.
    pub two_delta: f32,
    pub delta: f32,
}

/// Output of the encoder-side fused pass.
#[derive(Debug, Default)]
pub struct FusedEncodeOut {
    pub codes: Vec<i32>,
    pub escapes: Vec<f32>,
    pub recon: Vec<f32>,
}

/// Numerical floor for σ (shared with the Pallas kernel and the
/// predictor trait impls — one constant, one value).
pub use super::predictor::magnitude::SIGMA_EPS;

#[inline]
fn predict_mag(prev_abs: f32, m: &mut f32, p: &FusedParams, inv_sigma_prev: f32) -> f32 {
    // Delegates to the shared scalar EMA step so the fused kernel and
    // the `MagnitudePredictor` trait impls cannot drift apart.
    ema_norm_step(p.beta, m, prev_abs, p.mu_prev, inv_sigma_prev, p.mu_curr, p.sigma_curr)
}

/// The escape path is cold (outlined) to keep the common path branch-light.
#[cold]
fn escape(out: &mut FusedEncodeOut, x: f32) {
    out.codes.push(ESCAPE_CODE);
    out.escapes.push(x);
    out.recon.push(x);
}

/// One predicted-path quantizer step: code, reconstruction and the
/// in-bound test. Shared verbatim by the scalar twin, the chunk body and
/// the chunk tail — the single definition is what makes the paths
/// bit-identical.
#[inline]
fn encode_step_pred(
    x: f32,
    g_hat: f32,
    p: &FusedParams,
    inv_two_delta: f32,
) -> (i32, f32, bool) {
    // floor(x + 0.5) (round-half-up) — matches the Pallas kernel
    // exactly; jnp.round would be half-to-even and f32::round
    // half-away-from-zero, which disagree at bin boundaries.
    let code_f = ((x - g_hat) * inv_two_delta + 0.5).floor();
    let code = code_f as i32;
    let r = g_hat + code as f32 * p.two_delta;
    let ok = x.is_finite()
        && p.two_delta > 0.0
        && code_f.abs() <= CODE_RADIUS as f32
        && (r - x).abs() <= p.delta
        && r.is_finite();
    (code, r, ok)
}

/// Encoder-side fused pass.
///
/// `prev_abs` is `|g̃^(t-1)|` (empty slice on round 1 ⇒ no prediction,
/// memory untouched), `memory` is the EMA state (resized lazily), `signs`
/// the sign tensor from Alg. 2. Produces codes/escapes/reconstruction;
/// the caller owns entropy coding.
pub fn fused_encode(
    grad: &[f32],
    prev_abs: &[f32],
    memory: &mut Vec<f32>,
    signs: &[f32],
    p: &FusedParams,
    out: &mut FusedEncodeOut,
) {
    let n = grad.len();
    assert_eq!(signs.len(), n);
    let have_prev = !prev_abs.is_empty();
    if have_prev {
        assert_eq!(prev_abs.len(), n);
        if memory.len() != n {
            memory.clear();
            memory.resize(n, 0.0);
        }
    }
    out.codes.clear();
    out.codes.reserve(n);
    out.escapes.clear();
    out.recon.clear();
    out.recon.reserve(n);
    let inv_sigma_prev = 1.0 / p.sigma_prev.max(SIGMA_EPS);
    let inv_two_delta = if p.two_delta > 0.0 { 1.0 / p.two_delta } else { 0.0 };
    // The chunked kernel covers the warm-state hot path only; round 1
    // and degenerate bins (2Δ ≤ 0 ⇒ everything escapes) stay scalar.
    if have_prev && p.two_delta > 0.0 && !kernels::scalar_kernels() {
        encode_fast_prev(grad, prev_abs, memory, signs, p, inv_sigma_prev, inv_two_delta, out);
    } else {
        encode_scalar(grad, prev_abs, memory, signs, p, inv_sigma_prev, inv_two_delta, out);
    }
}

/// Scalar twin: one slice-zipped bounds-check-free pass.
#[allow(clippy::too_many_arguments)]
fn encode_scalar(
    grad: &[f32],
    prev_abs: &[f32],
    memory: &mut [f32],
    signs: &[f32],
    p: &FusedParams,
    inv_sigma_prev: f32,
    inv_two_delta: f32,
    out: &mut FusedEncodeOut,
) {
    if !prev_abs.is_empty() {
        for (((&x, &pa), m), &s) in
            grad.iter().zip(prev_abs.iter()).zip(memory.iter_mut()).zip(signs.iter())
        {
            let a_hat = predict_mag(pa, m, p, inv_sigma_prev);
            let (code, r, ok) = encode_step_pred(x, s * a_hat, p, inv_two_delta);
            if ok {
                out.codes.push(code);
                out.recon.push(r);
            } else {
                escape(out, x);
            }
        }
    } else {
        for &x in grad {
            // Round 1: no prediction term at all (not even `- 0.0`), so
            // the wire stream matches the seed bit-for-bit.
            let code_f = (x * inv_two_delta + 0.5).floor();
            let code = code_f as i32;
            let r = code as f32 * p.two_delta;
            if x.is_finite()
                && p.two_delta > 0.0
                && code_f.abs() <= CODE_RADIUS as f32
                && (r - x).abs() <= p.delta
                && r.is_finite()
            {
                out.codes.push(code);
                out.recon.push(r);
            } else {
                escape(out, x);
            }
        }
    }
}

/// Fast twin of the warm-state encode: `CHUNK`-wide array-ref chunks so
/// the per-lane loops have compile-time trip counts (autovectorizable),
/// with a per-chunk ok-mask — all-in-bound chunks bulk-extend the output,
/// chunks containing an escape fall back to a per-lane loop.
#[allow(clippy::too_many_arguments)]
fn encode_fast_prev(
    grad: &[f32],
    prev_abs: &[f32],
    memory: &mut [f32],
    signs: &[f32],
    p: &FusedParams,
    inv_sigma_prev: f32,
    inv_two_delta: f32,
    out: &mut FusedEncodeOut,
) {
    let n = grad.len();
    debug_assert_eq!(prev_abs.len(), n);
    debug_assert_eq!(memory.len(), n);
    debug_assert_eq!(signs.len(), n);
    let chunks = n / CHUNK;
    for c in 0..chunks {
        let base = c * CHUNK;
        // SAFETY: `base + CHUNK = (c + 1) * CHUNK ≤ chunks * CHUNK ≤ n`,
        // and `grad`, `prev_abs`, `signs` all have length exactly `n`
        // (asserted by the dispatcher, debug-asserted above), so each
        // `CHUNK`-wide array ref is fully in bounds.
        let (g, pa, s) = unsafe {
            (
                &*(grad.as_ptr().add(base) as *const [f32; CHUNK]),
                &*(prev_abs.as_ptr().add(base) as *const [f32; CHUNK]),
                &*(signs.as_ptr().add(base) as *const [f32; CHUNK]),
            )
        };
        // SAFETY: same bound as above with `memory.len() == n`; this is
        // the only live view into `memory` (the shared refs above point
        // into distinct slices), so the mutable array ref cannot alias.
        let m = unsafe { &mut *(memory.as_mut_ptr().add(base) as *mut [f32; CHUNK]) };
        let mut code = [0i32; CHUNK];
        let mut rec = [0f32; CHUNK];
        let mut all_ok = true;
        let mut ok = [false; CHUNK];
        for l in 0..CHUNK {
            let a_hat = predict_mag(pa[l], &mut m[l], p, inv_sigma_prev);
            let (ci, r, o) = encode_step_pred(g[l], s[l] * a_hat, p, inv_two_delta);
            code[l] = ci;
            rec[l] = r;
            ok[l] = o;
            all_ok &= o;
        }
        if all_ok {
            out.codes.extend_from_slice(&code);
            out.recon.extend_from_slice(&rec);
        } else {
            for l in 0..CHUNK {
                if ok[l] {
                    out.codes.push(code[l]);
                    out.recon.push(rec[l]);
                } else {
                    escape(out, g[l]);
                }
            }
        }
    }
    // Scalar tail for the final `n % CHUNK` elements — same shared
    // per-element step, so the seam is invisible in the output.
    for i in chunks * CHUNK..n {
        let a_hat = predict_mag(prev_abs[i], &mut memory[i], p, inv_sigma_prev);
        let (code, r, ok) = encode_step_pred(grad[i], signs[i] * a_hat, p, inv_two_delta);
        if ok {
            out.codes.push(code);
            out.recon.push(r);
        } else {
            escape(out, grad[i]);
        }
    }
}

/// Decoder-side fused pass: identical prediction + memory update, then
/// reconstruction from codes/escapes. Must mirror `fused_encode` exactly.
pub fn fused_decode(
    codes: &[i32],
    escapes: &[f32],
    prev_abs: &[f32],
    memory: &mut Vec<f32>,
    signs: &[f32],
    p: &FusedParams,
    recon: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let n = codes.len();
    if signs.len() != n {
        anyhow::bail!("sign length {} != codes {}", signs.len(), n);
    }
    let have_prev = !prev_abs.is_empty();
    if have_prev {
        if prev_abs.len() != n {
            anyhow::bail!("prev length {} != codes {}", prev_abs.len(), n);
        }
        if memory.len() != n {
            memory.clear();
            memory.resize(n, 0.0);
        }
    }
    recon.clear();
    recon.reserve(n);
    let inv_sigma_prev = 1.0 / p.sigma_prev.max(SIGMA_EPS);
    if have_prev && !kernels::scalar_kernels() {
        decode_fast_prev(codes, escapes, prev_abs, memory, signs, p, inv_sigma_prev, recon)
    } else {
        decode_scalar(codes, escapes, prev_abs, memory, signs, p, inv_sigma_prev, recon)
    }
}

#[allow(clippy::too_many_arguments)]
fn decode_scalar(
    codes: &[i32],
    escapes: &[f32],
    prev_abs: &[f32],
    memory: &mut [f32],
    signs: &[f32],
    p: &FusedParams,
    inv_sigma_prev: f32,
    recon: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let n = codes.len();
    let have_prev = !prev_abs.is_empty();
    let mut esc = escapes.iter();
    for i in 0..n {
        let g_hat = if have_prev {
            let a_hat = predict_mag(prev_abs[i], &mut memory[i], p, inv_sigma_prev);
            signs[i] * a_hat
        } else {
            0.0
        };
        if codes[i] == ESCAPE_CODE {
            let v = *esc
                .next()
                .ok_or_else(|| anyhow::anyhow!("escape stream exhausted"))?;
            recon.push(v);
        } else {
            recon.push(g_hat + codes[i] as f32 * p.two_delta);
        }
    }
    if esc.next().is_some() {
        anyhow::bail!("unconsumed escapes");
    }
    Ok(())
}

/// Fast twin of the warm-state decode — same chunking as the encoder;
/// escape-free chunks (the common case: escapes are rare by design) run
/// a branchless reconstruct loop.
#[allow(clippy::too_many_arguments)]
fn decode_fast_prev(
    codes: &[i32],
    escapes: &[f32],
    prev_abs: &[f32],
    memory: &mut [f32],
    signs: &[f32],
    p: &FusedParams,
    inv_sigma_prev: f32,
    recon: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let n = codes.len();
    debug_assert_eq!(prev_abs.len(), n);
    debug_assert_eq!(memory.len(), n);
    debug_assert_eq!(signs.len(), n);
    let chunks = n / CHUNK;
    let mut esc = 0usize;
    for c in 0..chunks {
        let base = c * CHUNK;
        // SAFETY: `base + CHUNK ≤ chunks * CHUNK ≤ n` and `codes`,
        // `prev_abs`, `signs` all have length `n` (bailed on mismatch by
        // the dispatcher, debug-asserted above) — the array refs are in
        // bounds.
        let (co, pa, s) = unsafe {
            (
                &*(codes.as_ptr().add(base) as *const [i32; CHUNK]),
                &*(prev_abs.as_ptr().add(base) as *const [f32; CHUNK]),
                &*(signs.as_ptr().add(base) as *const [f32; CHUNK]),
            )
        };
        // SAFETY: same bound with `memory.len() == n`; the only mutable
        // view, no aliasing with the shared refs above.
        let m = unsafe { &mut *(memory.as_mut_ptr().add(base) as *mut [f32; CHUNK]) };
        let mut ghat = [0f32; CHUNK];
        let mut any_escape = false;
        for l in 0..CHUNK {
            let a_hat = predict_mag(pa[l], &mut m[l], p, inv_sigma_prev);
            ghat[l] = s[l] * a_hat;
            any_escape |= co[l] == ESCAPE_CODE;
        }
        if !any_escape {
            for l in 0..CHUNK {
                recon.push(ghat[l] + co[l] as f32 * p.two_delta);
            }
        } else {
            for l in 0..CHUNK {
                if co[l] == ESCAPE_CODE {
                    let v = *escapes
                        .get(esc)
                        .ok_or_else(|| anyhow::anyhow!("escape stream exhausted"))?;
                    esc += 1;
                    recon.push(v);
                } else {
                    recon.push(ghat[l] + co[l] as f32 * p.two_delta);
                }
            }
        }
    }
    for i in chunks * CHUNK..n {
        let a_hat = predict_mag(prev_abs[i], &mut memory[i], p, inv_sigma_prev);
        let g_hat = signs[i] * a_hat;
        if codes[i] == ESCAPE_CODE {
            let v = *escapes
                .get(esc)
                .ok_or_else(|| anyhow::anyhow!("escape stream exhausted"))?;
            esc += 1;
            recon.push(v);
        } else {
            recon.push(g_hat + codes[i] as f32 * p.two_delta);
        }
    }
    if esc != escapes.len() {
        anyhow::bail!("unconsumed escapes");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::stats;

    fn params(grad: &[f32], prev_abs: &[f32], delta: f32, beta: f32) -> FusedParams {
        let abs: Vec<f32> = grad.iter().map(|x| x.abs()).collect();
        let (mu_curr, sigma_curr) = stats::mean_std(&abs);
        let (mu_prev, sigma_prev) = stats::mean_std(prev_abs);
        FusedParams { beta, mu_curr, sigma_curr, mu_prev, sigma_prev, two_delta: 2.0 * delta, delta }
    }

    #[test]
    fn encode_decode_agree() {
        let grad = vec![0.5f32, -0.3, 0.8, -0.2, 0.05, -0.9];
        let prev_abs = vec![0.4f32, 0.2, 0.7, 0.3, 0.1, 0.8];
        let signs = vec![1.0f32, -1.0, 1.0, -1.0, 0.0, -1.0];
        let p = params(&grad, &prev_abs, 0.01, 0.9);
        let mut mem_e = Vec::new();
        let mut out = FusedEncodeOut::default();
        fused_encode(&grad, &prev_abs, &mut mem_e, &signs, &p, &mut out);
        let mut mem_d = Vec::new();
        let mut recon = Vec::new();
        fused_decode(&out.codes, &out.escapes, &prev_abs, &mut mem_d, &signs, &p, &mut recon)
            .unwrap();
        assert_eq!(out.recon, recon);
        assert_eq!(mem_e, mem_d);
        for (r, g) in recon.iter().zip(&grad) {
            assert!((r - g).abs() <= 0.01 + 1e-7);
        }
    }

    #[test]
    fn round_one_no_prediction() {
        let grad = vec![0.55f32, -0.3];
        let signs = vec![0.0f32, 0.0];
        let p = params(&grad, &[], 0.1, 0.9);
        let mut mem = Vec::new();
        let mut out = FusedEncodeOut::default();
        fused_encode(&grad, &[], &mut mem, &signs, &p, &mut out);
        assert!(mem.is_empty()); // memory untouched on round 1
        // codes quantize g directly (pred = 0)
        assert_eq!(out.codes[0], (0.55f32 / 0.2 + 0.5).floor() as i32);
    }

    #[test]
    fn good_prediction_yields_small_codes() {
        // When sign & magnitude predictions are accurate, codes concentrate
        // near zero even with tiny delta.
        let n = 512;
        let prev_abs: Vec<f32> = (0..n).map(|i| 0.5 + 0.3 * ((i as f32) / 64.0).sin()).collect();
        // current = prev pattern (stationary), same signs
        let grad: Vec<f32> = prev_abs.iter().map(|&a| a).collect();
        let signs = vec![1.0f32; n];
        let mut mem = vec![0.0f32; n];
        // Warm the memory with several identical rounds.
        let p = params(&grad, &prev_abs, 0.05, 0.5);
        let mut out = FusedEncodeOut::default();
        for _ in 0..20 {
            let mut m2 = mem.clone();
            fused_encode(&grad, &prev_abs, &mut m2, &signs, &p, &mut out);
            mem = m2;
        }
        let zero_frac =
            out.codes.iter().filter(|&&c| c == 0).count() as f64 / out.codes.len() as f64;
        assert!(zero_frac > 0.9, "zero_frac={zero_frac}");
    }

    #[test]
    fn property_bound_and_mirror() {
        prop::check("fused bound+mirror", 120, |rng| {
            let n = prop::arb_len(rng, 3000);
            let grad = prop::arb_gradient(rng, n);
            let prev: Vec<f32> = prop::arb_gradient(rng, n).iter().map(|x| x.abs()).collect();
            let signs: Vec<f32> = (0..n)
                .map(|_| match rng.next_below(3) {
                    0 => -1.0,
                    1 => 0.0,
                    _ => 1.0,
                })
                .collect();
            let delta = prop::arb_error_bound(rng) as f32;
            let p = params(&grad, &prev, delta, 0.9);
            let mut mem_e = Vec::new();
            let mut out = FusedEncodeOut::default();
            fused_encode(&grad, &prev, &mut mem_e, &signs, &p, &mut out);
            for i in 0..n {
                if grad[i].is_finite() && (out.recon[i] - grad[i]).abs() > delta * 1.0001 {
                    return Err(format!("bound violated at {i}"));
                }
            }
            let mut mem_d = Vec::new();
            let mut recon = Vec::new();
            fused_decode(&out.codes, &out.escapes, &prev, &mut mem_d, &signs, &p, &mut recon)
                .map_err(|e| e.to_string())?;
            if recon
                .iter()
                .zip(&out.recon)
                .any(|(a, b)| !(a == b || (a.is_nan() && b.is_nan())))
            {
                return Err("decoder recon mismatch".into());
            }
            if mem_e != mem_d {
                return Err("memory divergence".into());
            }
            Ok(())
        });
    }

    #[test]
    fn scalar_and_fast_twins_agree_bitwise() {
        prop::check("fused scalar==fast", 80, |rng| {
            let n = prop::arb_len(rng, 3000);
            let grad = prop::arb_gradient(rng, n);
            let prev: Vec<f32> = prop::arb_gradient(rng, n).iter().map(|x| x.abs()).collect();
            let signs: Vec<f32> = (0..n)
                .map(|_| match rng.next_below(3) {
                    0 => -1.0,
                    1 => 0.0,
                    _ => 1.0,
                })
                .collect();
            let delta = prop::arb_error_bound(rng) as f32;
            let p = params(&grad, &prev, delta, 0.9);

            let mut mem_f = Vec::new();
            let mut fast = FusedEncodeOut::default();
            fused_encode(&grad, &prev, &mut mem_f, &signs, &p, &mut fast);
            let (mut mem_s, mut slow) = (Vec::new(), FusedEncodeOut::default());
            kernels::with_scalar_kernels(|| {
                fused_encode(&grad, &prev, &mut mem_s, &signs, &p, &mut slow);
            });
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            if fast.codes != slow.codes {
                return Err("codes diverge".into());
            }
            if bits(&fast.escapes) != bits(&slow.escapes) {
                return Err("escapes diverge".into());
            }
            if bits(&fast.recon) != bits(&slow.recon) {
                return Err("encode recon diverges".into());
            }
            if bits(&mem_f) != bits(&mem_s) {
                return Err("encode memory diverges".into());
            }

            let (mut dm_f, mut dr_f) = (Vec::new(), Vec::new());
            fused_decode(&fast.codes, &fast.escapes, &prev, &mut dm_f, &signs, &p, &mut dr_f)
                .map_err(|e| e.to_string())?;
            let (mut dm_s, mut dr_s) = (Vec::new(), Vec::new());
            kernels::with_scalar_kernels(|| {
                fused_decode(&fast.codes, &fast.escapes, &prev, &mut dm_s, &signs, &p, &mut dr_s)
                    .map_err(|e| e.to_string())
            })?;
            if bits(&dr_f) != bits(&dr_s) {
                return Err("decode recon diverges".into());
            }
            if bits(&dm_f) != bits(&dm_s) {
                return Err("decode memory diverges".into());
            }
            Ok(())
        });
    }

    #[test]
    fn fast_decode_rejects_bad_escape_streams() {
        // The fast twin must keep the scalar twin's stream-integrity
        // errors: a missing escape value and a surplus one both fail.
        let n = 40;
        let grad: Vec<f32> = (0..n).map(|i| (i as f32) * 0.01 - 0.2).collect();
        let prev: Vec<f32> = (0..n).map(|i| 0.1 + (i as f32) * 0.005).collect();
        let signs = vec![1.0f32; n];
        let p = params(&grad, &prev, 0.01, 0.9);
        let mut mem = Vec::new();
        let mut out = FusedEncodeOut::default();
        fused_encode(&grad, &prev, &mut mem, &signs, &p, &mut out);
        // Force an escape code without its escape value.
        let mut codes = out.codes.clone();
        codes[3] = ESCAPE_CODE;
        let (mut dm, mut dr) = (Vec::new(), Vec::new());
        assert!(
            fused_decode(&codes, &out.escapes, &prev, &mut dm, &signs, &p, &mut dr).is_err()
        );
        // Surplus escape value.
        let mut escapes = out.escapes.clone();
        escapes.push(1.0);
        assert!(
            fused_decode(&out.codes, &escapes, &prev, &mut dm, &signs, &p, &mut dr).is_err()
        );
    }
}
