//! A from-scratch LZ77 (LZSS-style) byte compressor with a hash-chain
//! match finder — the "own" lossless backend used for ablating the Zstd
//! stage (DESIGN.md §3). Token format:
//!
//! ```text
//! token   := literal_run | match
//! literal := 0x00 varint(len) bytes[len]
//! match   := 0x01 varint(len) varint(dist)      (len >= MIN_MATCH)
//! ```

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 255 + MIN_MATCH;
const WINDOW: usize = 1 << 16;
const HASH_BITS: usize = 15;
const MAX_CHAIN: usize = 32;

fn write_varint(out: &mut Vec<u8>, mut v: usize) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(buf: &[u8], pos: &mut usize) -> anyhow::Result<usize> {
    let mut v = 0usize;
    let mut shift = 0;
    loop {
        let b = *buf.get(*pos).ok_or_else(|| anyhow::anyhow!("varint underrun"))?;
        *pos += 1;
        v |= ((b & 0x7f) as usize) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 56 {
            anyhow::bail!("varint too long");
        }
    }
}

#[inline]
fn hash4(buf: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([buf[i], buf[i + 1], buf[i + 2], buf[i + 3]]);
    (v.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
}

/// Compress `input` with LZ77. Always succeeds; incompressible data grows
/// by ~1/128 from literal-run headers.
pub fn compress(input: &[u8]) -> Vec<u8> {
    let n = input.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    write_varint(&mut out, n);
    if n == 0 {
        return out;
    }
    let mut head = vec![usize::MAX; 1 << HASH_BITS];
    let mut prev = vec![usize::MAX; n];
    let mut i = 0usize;
    let mut lit_start = 0usize;

    let flush_literals = |out: &mut Vec<u8>, from: usize, to: usize| {
        let mut s = from;
        while s < to {
            let run = (to - s).min(1 << 20);
            out.push(0x00);
            write_varint(out, run);
            out.extend_from_slice(&input[s..s + run]);
            s += run;
        }
    };

    while i < n {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= n {
            let h = hash4(input, i);
            let old_head = head[h];
            let mut cand = old_head;
            let mut chain = 0;
            while cand != usize::MAX && chain < MAX_CHAIN && i - cand <= WINDOW {
                // Extend match.
                let max_len = (n - i).min(MAX_MATCH);
                let mut l = 0usize;
                while l < max_len && input[cand + l] == input[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = i - cand;
                    if l >= MAX_MATCH {
                        break;
                    }
                }
                cand = prev[cand];
                chain += 1;
            }
            prev[i] = old_head;
            head[h] = i;
        }
        if best_len >= MIN_MATCH {
            flush_literals(&mut out, lit_start, i);
            out.push(0x01);
            write_varint(&mut out, best_len);
            write_varint(&mut out, best_dist);
            // Insert hash entries inside the match (sparse, every pos).
            let end = (i + best_len).min(n.saturating_sub(MIN_MATCH));
            let mut j = i + 1;
            while j < end {
                let h = hash4(input, j);
                prev[j] = head[h];
                head[h] = j;
                j += 1;
            }
            i += best_len;
            lit_start = i;
        } else {
            i += 1;
        }
    }
    flush_literals(&mut out, lit_start, n);
    out
}

/// Decompress an LZ77 stream produced by [`compress`].
pub fn decompress(buf: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut pos = 0usize;
    let n = read_varint(buf, &mut pos)?;
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let tag = *buf.get(pos).ok_or_else(|| anyhow::anyhow!("token underrun"))?;
        pos += 1;
        match tag {
            0x00 => {
                let len = read_varint(buf, &mut pos)?;
                if pos + len > buf.len() || out.len() + len > n {
                    anyhow::bail!("literal overrun");
                }
                out.extend_from_slice(&buf[pos..pos + len]);
                pos += len;
            }
            0x01 => {
                let len = read_varint(buf, &mut pos)?;
                let dist = read_varint(buf, &mut pos)?;
                if dist == 0 || dist > out.len() || out.len() + len > n {
                    anyhow::bail!("bad match dist={dist} len={len} at {}", out.len());
                }
                let start = out.len() - dist;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
            t => anyhow::bail!("bad token {t}"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_text() {
        let data = b"the quick brown fox jumps over the lazy dog the quick brown fox".to_vec();
        let c = compress(&data);
        assert_eq!(decompress(&c).unwrap(), data);
        assert!(c.len() < data.len());
    }

    #[test]
    fn roundtrip_empty_and_tiny() {
        for data in [vec![], vec![42u8], vec![1, 2, 3]] {
            let c = compress(&data);
            assert_eq!(decompress(&c).unwrap(), data);
        }
    }

    #[test]
    fn repetitive_compresses_well() {
        let data = vec![7u8; 100_000];
        let c = compress(&data);
        assert!(c.len() < 2000, "len={}", c.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn overlapping_match() {
        // "abcabcabc..." forces dist < len copies.
        let data: Vec<u8> = (0..1000).map(|i| b"abc"[i % 3]).collect();
        let c = compress(&data);
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn property_roundtrip_random() {
        prop::check("lz roundtrip", 60, |rng| {
            let n = prop::arb_len(rng, 20_000);
            // Mix of random and repetitive segments.
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                if rng.chance(0.5) {
                    let b = rng.next_below(256) as u8;
                    let run = 1 + rng.next_below(100);
                    data.extend(std::iter::repeat(b).take(run.min(n - data.len())));
                } else {
                    data.push(rng.next_below(256) as u8);
                }
            }
            let c = compress(&data);
            let d = decompress(&c).map_err(|e| e.to_string())?;
            if d != data {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn corrupt_stream_errors_not_panics() {
        let data = b"hello world hello world hello world".to_vec();
        let mut c = compress(&data);
        if c.len() > 4 {
            let idx = c.len() - 2;
            c[idx] = 0xFF;
            let _ = decompress(&c); // must not panic
        }
        let _ = decompress(&[0x80, 0x80, 0x80]); // bad varint
    }
}
