//! Stage 1: the gradient-aware predictor — the paper's core innovation.
//!
//! * [`magnitude`] — cross-round magnitude prediction: per-epoch
//!   normalization + exponential moving average (Alg. 1), plus the
//!   ablation variants of Table 1.
//! * [`sign`] — sign prediction: full-batch oscillation flip (Fig. 5) or
//!   mini-batch kernel-level dominant sign via Eq. 5 consistency (Fig. 7).
//! * [`bitmap`] — the two-level bitmap side channel (Fig. 8).

pub mod bitmap;
pub mod magnitude;
pub mod sign;
