//! Stage 1: the gradient-aware predictor — the paper's core innovation,
//! behind a **pluggable per-layer predictor API**.
//!
//! * [`magnitude`] — cross-round magnitude prediction: the
//!   [`MagnitudePredictor`] trait (plan → predict → absorb) with the
//!   normalized-EMA production predictor (Alg. 1), the Lorenzo-in-time
//!   and zero predictors, the `pred=` selector registry, plus the
//!   Table-1 ablation variants.
//! * [`sign`] — sign prediction: the [`SignPredictor`] trait with the
//!   full-batch oscillation flip (Fig. 5), the mini-batch kernel-level
//!   dominant sign via Eq. 5 consistency (Fig. 7), and the off policy;
//!   the `sign=` selector registry.
//! * [`bitmap`] — the two-level bitmap side channel (Fig. 8).
//!
//! Selection is carried by [`PredictorSpec`] (the `pred=`/`sign=` keys
//! of the `CodecSpec` grammar). Frames are self-describing: every lossy
//! layer section produced under a non-default magnitude predictor
//! records the [`magnitude::PredTag`] actually used (and the EMA β), so
//! the decoder reconstructs with zero out-of-band configuration —
//! `pred=auto` races the fixed predictors per layer each round and
//! records the per-round winner.

pub mod bitmap;
pub mod magnitude;
pub mod sign;

pub use magnitude::{MagnitudePredictor, MagnitudeSel, PredTag, DEFAULT_BETA};
pub use sign::{SignPredictor, SignSel};

/// The predict-stage selection of one codec — which magnitude predictor
/// and which sign policy run (CodecSpec keys `pred=` / `sign=`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PredictorSpec {
    pub mag: MagnitudeSel,
    pub sign: SignSel,
}

impl PredictorSpec {
    /// The classic pipeline: implicit EMA magnitude + regime-driven sign
    /// (what `fedgec` means when both keys are omitted).
    pub fn is_default(&self) -> bool {
        *self == PredictorSpec::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_classic_pipeline() {
        let d = PredictorSpec::default();
        assert_eq!(d.mag, MagnitudeSel::Ema);
        assert_eq!(d.sign, SignSel::Auto);
        assert!(d.is_default());
        assert!(!PredictorSpec { mag: MagnitudeSel::Auto, sign: SignSel::Auto }.is_default());
    }
}
