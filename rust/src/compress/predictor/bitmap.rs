//! The two-level bitmap side channel (paper §4.4, Fig. 8).
//!
//! Level 1: one bit per kernel — is this kernel sign-predicted?
//! Level 2: one bit per *predicted* kernel — dominant sign (1 = positive,
//! 0 = negative). Level 2 is only as long as the popcount of level 1.
//!
//! The serialized bitmap is later swept into the lossless backend together
//! with the entropy-coded residuals, exactly as the paper bundles it.

use crate::util::bitio::{BitReader, BitWriter};

/// Decoded two-level bitmap.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelBitmap {
    /// Per-kernel: predicted?
    pub predicted: Vec<bool>,
    /// Per-predicted-kernel dominant sign: `true` = positive.
    pub signs: Vec<bool>,
}

impl KernelBitmap {
    /// Build from per-kernel decisions: `None` = unpredicted,
    /// `Some(positive)` = predicted with that dominant sign.
    pub fn from_decisions(decisions: &[Option<bool>]) -> Self {
        let predicted: Vec<bool> = decisions.iter().map(|d| d.is_some()).collect();
        let signs: Vec<bool> = decisions.iter().filter_map(|d| *d).collect();
        KernelBitmap { predicted, signs }
    }

    /// Number of predicted kernels.
    pub fn predicted_count(&self) -> usize {
        self.signs.len()
    }

    /// Fraction of kernels predicted (the paper's "prediction ratio" P).
    pub fn prediction_ratio(&self) -> f64 {
        if self.predicted.is_empty() {
            0.0
        } else {
            self.signs.len() as f64 / self.predicted.len() as f64
        }
    }

    /// Serialize: `u32 n_kernels` + level-1 bits + level-2 bits.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.predicted.len() as u32).to_le_bytes());
        let mut w = BitWriter::new();
        for &p in &self.predicted {
            w.put_bit(p);
        }
        for &s in &self.signs {
            w.put_bit(s);
        }
        out.extend_from_slice(&w.into_bytes());
        out
    }

    /// Deserialize from the byte layout of [`encode`].
    pub fn decode(buf: &[u8]) -> anyhow::Result<KernelBitmap> {
        Self::decode_bounded(buf, u32::MAX as usize)
    }

    /// [`Self::decode`] with a caller-known cap on the kernel count (the
    /// layer's numel — every kernel carries at least one element). The
    /// declared count is validated against both the cap and the bits the
    /// buffer actually holds **before** any allocation, so a corrupt
    /// stream cannot force a multi-GB `Vec` reservation — the same
    /// untrusted-payload pattern as `EntropyCoder::decode_bounded`.
    pub fn decode_bounded(buf: &[u8], max_kernels: usize) -> anyhow::Result<KernelBitmap> {
        if buf.len() < 4 {
            anyhow::bail!("bitmap too short");
        }
        let n = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        anyhow::ensure!(
            n <= max_kernels,
            "bitmap declares {n} kernels, expected at most {max_kernels}"
        );
        // Level 1 alone needs ⌈n/8⌉ payload bytes; reject impossible
        // headers before reserving level-1/level-2 capacity.
        anyhow::ensure!(
            n.div_ceil(8) <= buf.len() - 4,
            "bitmap declares {n} kernels but carries only {} payload bytes",
            buf.len() - 4
        );
        let mut r = BitReader::new(&buf[4..]);
        let mut predicted = Vec::with_capacity(n);
        for _ in 0..n {
            predicted.push(r.get_bit().ok_or_else(|| anyhow::anyhow!("level-1 underrun"))?);
        }
        let n_pred = predicted.iter().filter(|&&p| p).count();
        let mut signs = Vec::with_capacity(n_pred);
        for _ in 0..n_pred {
            signs.push(r.get_bit().ok_or_else(|| anyhow::anyhow!("level-2 underrun"))?);
        }
        Ok(KernelBitmap { predicted, signs })
    }

    /// Expand to per-kernel decisions (inverse of `from_decisions`).
    pub fn decisions(&self) -> Vec<Option<bool>> {
        let mut signs = self.signs.iter();
        self.predicted
            .iter()
            .map(|&p| if p { Some(*signs.next().expect("sign bit")) } else { None })
            .collect()
    }

    /// Serialized size in bytes.
    pub fn byte_size(&self) -> usize {
        4 + (self.predicted.len() + self.signs.len()).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_basic() {
        let decisions = vec![Some(true), None, Some(false), Some(true), None];
        let bm = KernelBitmap::from_decisions(&decisions);
        assert_eq!(bm.predicted_count(), 3);
        let bytes = bm.encode();
        let got = KernelBitmap::decode(&bytes).unwrap();
        assert_eq!(got, bm);
        assert_eq!(got.decisions(), decisions);
    }

    #[test]
    fn empty_bitmap() {
        let bm = KernelBitmap::from_decisions(&[]);
        let got = KernelBitmap::decode(&bm.encode()).unwrap();
        assert_eq!(got.predicted_count(), 0);
        assert_eq!(bm.prediction_ratio(), 0.0);
    }

    #[test]
    fn overhead_formula_matches_paper_example() {
        // Paper §4.4: fp32, K=3x3, P=0.6 -> bitmap ≈ (1+P)/(b·K) of raw
        // before lossless. For 10_000 kernels of 9 elements:
        let n_kernels = 10_000usize;
        let decisions: Vec<Option<bool>> =
            (0..n_kernels).map(|i| if i % 5 < 3 { Some(i % 2 == 0) } else { None }).collect();
        let bm = KernelBitmap::from_decisions(&decisions);
        let raw_bytes = n_kernels * 9 * 4;
        let frac = bm.byte_size() as f64 / raw_bytes as f64;
        let expect = (1.0 + 0.6) / (32.0 * 9.0); // ≈ 0.56%
        assert!((frac - expect).abs() < 0.002, "frac={frac} expect={expect}");
    }

    #[test]
    fn property_roundtrip_random() {
        prop::check("bitmap roundtrip", 100, |rng| {
            let n = prop::arb_len(rng, 3000);
            let decisions: Vec<Option<bool>> = (0..n)
                .map(|_| {
                    if rng.chance(0.5) {
                        Some(rng.chance(0.5))
                    } else {
                        None
                    }
                })
                .collect();
            let bm = KernelBitmap::from_decisions(&decisions);
            let got = KernelBitmap::decode(&bm.encode()).map_err(|e| e.to_string())?;
            if got.decisions() != decisions {
                return Err("decision mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn truncated_decode_errors() {
        let decisions = vec![Some(true); 100];
        let bytes = KernelBitmap::from_decisions(&decisions).encode();
        assert!(KernelBitmap::decode(&bytes[..5]).is_err());
        assert!(KernelBitmap::decode(&[]).is_err());
    }

    #[test]
    fn bounded_decode_guards_declared_count() {
        let decisions = vec![Some(true), None, Some(false)];
        let bytes = KernelBitmap::from_decisions(&decisions).encode();
        assert_eq!(KernelBitmap::decode_bounded(&bytes, 3).unwrap().decisions(), decisions);
        // Cap below the declared count is an error, not an allocation.
        assert!(KernelBitmap::decode_bounded(&bytes, 2).is_err());
        // An adversarial header declaring u32::MAX kernels over a tiny
        // buffer dies on the plausibility check even unbounded.
        let mut evil = u32::MAX.to_le_bytes().to_vec();
        evil.extend_from_slice(&[0xAA; 8]);
        assert!(KernelBitmap::decode_bounded(&evil, usize::MAX).is_err());
        assert!(KernelBitmap::decode(&evil).is_err());
    }
}
