//! Gradient **sign** predictor (paper Alg. 2).
//!
//! Two regimes:
//!
//! * **Full-batch GD** — gradients oscillate with strong (anti-)correlation
//!   between consecutive rounds (paper Fig. 5, Eq. 4). The client computes
//!   the scalar gradient correlation `c` between `g̃^(t-1)` and `g^(t)`;
//!   if `c < 0` the previous sign tensor is globally flipped. Only the
//!   flip **bit** travels to the server.
//!
//! * **Mini-batch** — per-element signs are too noisy; instead each
//!   convolutional kernel `K_{o,i}` is tested for sign consistency
//!   (Eq. 5). Kernels at or above threshold τ get their dominant sign
//!   assigned to **all** their elements; others are left unpredicted
//!   (sign 0 ⇒ ĝ = 0 for those elements, i.e. plain SZ-style residual).
//!   The kernel decisions travel in the two-level bitmap (Fig. 8).
//!
//! Non-conv layers have no kernel structure and are left unpredicted in
//! mini-batch mode, exactly like sub-threshold kernels.

use super::bitmap::KernelBitmap;
use crate::tensor::LayerKind;
use crate::util::stats;

/// Training-regime switch (paper Alg. 2 `FullBatchGD` flag + τ).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SignMode {
    /// Full-batch gradient descent: oscillation flip.
    FullBatch,
    /// Mini-batch: kernel consistency threshold τ ∈ [0,1].
    MiniBatch { tau: f64 },
}

/// Side information produced by the client-side predictor; travels in the
/// payload so the server reproduces the same sign tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum SignMeta {
    /// No sign prediction for this layer.
    None,
    /// Full-batch: whether to flip the previous sign tensor.
    Flip(bool),
    /// Mini-batch conv layer: the two-level kernel bitmap.
    Bitmap(KernelBitmap),
}

impl SignMeta {
    /// Serialize with a 1-byte tag.
    pub fn encode(&self) -> Vec<u8> {
        match self {
            SignMeta::None => vec![0],
            SignMeta::Flip(f) => vec![1, *f as u8],
            SignMeta::Bitmap(bm) => {
                let mut out = vec![2];
                out.extend_from_slice(&bm.encode());
                out
            }
        }
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<SignMeta> {
        match buf.first() {
            Some(0) => Ok(SignMeta::None),
            Some(1) => Ok(SignMeta::Flip(*buf.get(1).ok_or_else(|| anyhow::anyhow!("flip underrun"))? != 0)),
            Some(2) => Ok(SignMeta::Bitmap(KernelBitmap::decode(&buf[1..])?)),
            _ => anyhow::bail!("bad sign meta"),
        }
    }
}

/// Statistics from one layer's sign prediction (for Table 5 / Fig. 7).
#[derive(Debug, Clone, Copy, Default)]
pub struct SignStats {
    pub kernels_total: usize,
    pub kernels_predicted: usize,
    /// Elements whose predicted sign disagreed with the true gradient sign
    /// (zeros in either excluded).
    pub sign_mismatches: usize,
    /// Elements carrying a predicted (nonzero) sign.
    pub elements_predicted: usize,
}

impl SignStats {
    pub fn prediction_ratio(&self) -> f64 {
        if self.kernels_total == 0 {
            0.0
        } else {
            self.kernels_predicted as f64 / self.kernels_total as f64
        }
    }
    pub fn mismatch_rate(&self) -> f64 {
        if self.elements_predicted == 0 {
            0.0
        } else {
            self.sign_mismatches as f64 / self.elements_predicted as f64
        }
    }
}

/// Client-side sign prediction (Alg. 2). Returns the elementwise sign
/// tensor `S ∈ {-1,0,+1}`, the side info for the server, and stats.
///
/// `prev_recon`/`prev_sign` are the previous round's reconstructed
/// gradient and sign tensor (None on round 1).
pub fn predict_signs(
    grad: &[f32],
    kind: &LayerKind,
    mode: SignMode,
    prev_recon: Option<&[f32]>,
    prev_sign: Option<&[f32]>,
) -> (Vec<f32>, SignMeta, SignStats) {
    match mode {
        SignMode::FullBatch => {
            let (Some(prev), Some(psign)) = (prev_recon, prev_sign) else {
                return (vec![0.0; grad.len()], SignMeta::Flip(false), SignStats::default());
            };
            let c = stats::gradient_correlation(prev, grad);
            let flip = c < 0.0;
            let f = if flip { -1.0 } else { 1.0 };
            let signs: Vec<f32> = psign.iter().map(|&s| f * s).collect();
            let stats = mismatch_stats(&signs, grad, 0, 0);
            (signs, SignMeta::Flip(flip), stats)
        }
        SignMode::MiniBatch { tau } => {
            let Some(t) = kind.kernel_size() else {
                // Non-conv layer: no structural sign prediction.
                return (vec![0.0; grad.len()], SignMeta::None, SignStats::default());
            };
            let n_kernels = grad.len() / t;
            let mut signs = vec![0.0f32; grad.len()];
            let mut decisions = Vec::with_capacity(n_kernels);
            let mut predicted = 0usize;
            // Single pass per kernel: P/N/Z counts give both the Eq. 5
            // consistency and the dominant sign (hot path, §Perf).
            let half = t.div_ceil(2);
            let den = (t - half).max(1) as f64;
            for k in 0..n_kernels {
                let kernel = &grad[k * t..(k + 1) * t];
                let (mut p, mut n) = (0usize, 0usize);
                for &x in kernel {
                    p += (x > 0.0) as usize;
                    n += (x < 0.0) as usize;
                }
                let z = t - p - n;
                let consistency = (((p.max(n) + z) as f64 - half as f64) / den).clamp(0.0, 1.0);
                let ok = if t <= 1 { true } else { consistency >= tau };
                if ok {
                    let s = if p > n { 1.0f32 } else { -1.0 };
                    decisions.push(Some(s > 0.0));
                    signs[k * t..(k + 1) * t].fill(s);
                    predicted += 1;
                } else {
                    decisions.push(None);
                }
            }
            let meta = SignMeta::Bitmap(KernelBitmap::from_decisions(&decisions));
            let stats = mismatch_stats(&signs, grad, n_kernels, predicted);
            (signs, meta, stats)
        }
    }
}

/// Server-side sign reconstruction (Alg. 4 line 11): rebuild the exact
/// same sign tensor from the side info + mirrored state.
pub fn reconstruct_signs(
    meta: &SignMeta,
    numel: usize,
    kind: &LayerKind,
    prev_sign: Option<&[f32]>,
) -> anyhow::Result<Vec<f32>> {
    match meta {
        SignMeta::None => Ok(vec![0.0; numel]),
        SignMeta::Flip(flip) => {
            let Some(psign) = prev_sign else {
                return Ok(vec![0.0; numel]);
            };
            let f = if *flip { -1.0 } else { 1.0 };
            Ok(psign.iter().map(|&s| f * s).collect())
        }
        SignMeta::Bitmap(bm) => {
            let t = kind
                .kernel_size()
                .ok_or_else(|| anyhow::anyhow!("bitmap sign meta on non-conv layer"))?;
            if bm.predicted.len() * t != numel {
                anyhow::bail!(
                    "bitmap kernel count {} x {} != numel {}",
                    bm.predicted.len(),
                    t,
                    numel
                );
            }
            let mut signs = vec![0.0f32; numel];
            let mut sign_bits = bm.signs.iter();
            for (k, &p) in bm.predicted.iter().enumerate() {
                if p {
                    let s = if *sign_bits.next().expect("sign bit") { 1.0 } else { -1.0 };
                    signs[k * t..(k + 1) * t].fill(s);
                }
            }
            Ok(signs)
        }
    }
}

fn mismatch_stats(signs: &[f32], grad: &[f32], kernels_total: usize, kernels_predicted: usize) -> SignStats {
    let mut st = SignStats { kernels_total, kernels_predicted, ..Default::default() };
    for (&s, &g) in signs.iter().zip(grad) {
        if s != 0.0 {
            st.elements_predicted += 1;
            if g != 0.0 && (s > 0.0) != (g > 0.0) {
                st.sign_mismatches += 1;
            }
        }
    }
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::LayerKind;
    use crate::util::rng::Rng;

    fn conv(kernels: usize, t: usize) -> LayerKind {
        LayerKind::Conv { out_ch: kernels, in_ch: 1, kh: 1, kw: t }
    }

    #[test]
    fn minibatch_consistent_kernel_predicted() {
        // One fully-positive kernel, one mixed kernel (consistency 0).
        let grad = vec![1.0f32, 2.0, 3.0, 1.0, -1.0, 1.0, -1.0, 1.0];
        let kind = LayerKind::Conv { out_ch: 2, in_ch: 1, kh: 2, kw: 2 };
        let (signs, meta, st) = predict_signs(&grad, &kind, SignMode::MiniBatch { tau: 0.9 }, None, None);
        assert_eq!(&signs[..4], &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(&signs[4..], &[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(st.kernels_predicted, 1);
        assert_eq!(st.kernels_total, 2);
        match meta {
            SignMeta::Bitmap(bm) => assert_eq!(bm.decisions(), vec![Some(true), None]),
            _ => panic!("expected bitmap"),
        }
    }

    #[test]
    fn minibatch_non_conv_no_prediction() {
        let grad = vec![1.0f32; 10];
        let (signs, meta, _) =
            predict_signs(&grad, &LayerKind::Other, SignMode::MiniBatch { tau: 0.5 }, None, None);
        assert!(signs.iter().all(|&s| s == 0.0));
        assert_eq!(meta, SignMeta::None);
    }

    #[test]
    fn fullbatch_flip_on_anticorrelation() {
        let prev = vec![1.0f32, -2.0, 3.0];
        let psign = vec![1.0f32, -1.0, 1.0];
        let grad = vec![-1.0f32, 2.0, -3.0]; // perfectly anti-correlated
        let (signs, meta, _) =
            predict_signs(&grad, &LayerKind::Other, SignMode::FullBatch, Some(&prev), Some(&psign));
        assert_eq!(meta, SignMeta::Flip(true));
        assert_eq!(signs, vec![-1.0, 1.0, -1.0]);
    }

    #[test]
    fn fullbatch_keep_on_correlation() {
        let prev = vec![1.0f32, -2.0, 3.0];
        let psign = vec![1.0f32, -1.0, 1.0];
        let (signs, meta, _) =
            predict_signs(&prev.clone(), &LayerKind::Other, SignMode::FullBatch, Some(&prev), Some(&psign));
        assert_eq!(meta, SignMeta::Flip(false));
        assert_eq!(signs, psign);
    }

    #[test]
    fn server_reconstruction_matches_client() {
        let mut rng = Rng::new(17);
        let t = 9;
        let n_kernels = 64;
        // Kernels with strong dominant-sign structure.
        let mut grad = Vec::with_capacity(n_kernels * t);
        for _ in 0..n_kernels {
            let dom: f32 = if rng.chance(0.5) { 1.0 } else { -1.0 };
            for _ in 0..t {
                let flip = rng.chance(0.15);
                grad.push(dom * if flip { -1.0 } else { 1.0 } * (0.1 + rng.next_f32()));
            }
        }
        let kind = conv(n_kernels, t);
        let (signs, meta, _) =
            predict_signs(&grad, &kind, SignMode::MiniBatch { tau: 0.5 }, None, None);
        // Roundtrip meta through bytes like the payload does.
        let decoded = SignMeta::decode(&meta.encode()).unwrap();
        let recon = reconstruct_signs(&decoded, grad.len(), &kind, None).unwrap();
        assert_eq!(signs, recon);
    }

    #[test]
    fn sign_meta_encode_roundtrip() {
        for meta in [
            SignMeta::None,
            SignMeta::Flip(true),
            SignMeta::Flip(false),
            SignMeta::Bitmap(KernelBitmap::from_decisions(&[Some(true), None, Some(false)])),
        ] {
            assert_eq!(SignMeta::decode(&meta.encode()).unwrap(), meta);
        }
    }

    #[test]
    fn bitmap_size_mismatch_errors() {
        let bm = KernelBitmap::from_decisions(&[Some(true); 4]);
        let err = reconstruct_signs(&SignMeta::Bitmap(bm), 100, &conv(4, 9), None);
        assert!(err.is_err());
    }

    #[test]
    fn tau_one_only_perfect_kernels() {
        let grad = vec![1.0f32, 1.0, 1.0, 1.0, 1.0, -1.0];
        let kind = LayerKind::Conv { out_ch: 2, in_ch: 1, kh: 1, kw: 3 };
        let (_, meta, st) = predict_signs(&grad, &kind, SignMode::MiniBatch { tau: 1.0 }, None, None);
        assert_eq!(st.kernels_predicted, 1);
        match meta {
            SignMeta::Bitmap(bm) => assert_eq!(bm.decisions(), vec![Some(true), None]),
            _ => panic!(),
        }
    }
}
