//! Gradient **sign** predictor (paper Alg. 2).
//!
//! Two regimes:
//!
//! * **Full-batch GD** — gradients oscillate with strong (anti-)correlation
//!   between consecutive rounds (paper Fig. 5, Eq. 4). The client computes
//!   the scalar gradient correlation `c` between `g̃^(t-1)` and `g^(t)`;
//!   if `c < 0` the previous sign tensor is globally flipped. Only the
//!   flip **bit** travels to the server.
//!
//! * **Mini-batch** — per-element signs are too noisy; instead each
//!   convolutional kernel `K_{o,i}` is tested for sign consistency
//!   (Eq. 5). Kernels at or above threshold τ get their dominant sign
//!   assigned to **all** their elements; others are left unpredicted
//!   (sign 0 ⇒ ĝ = 0 for those elements, i.e. plain SZ-style residual).
//!   The kernel decisions travel in the two-level bitmap (Fig. 8).
//!
//! Non-conv layers have no kernel structure and are left unpredicted in
//! mini-batch mode, exactly like sub-threshold kernels.

use super::bitmap::KernelBitmap;
use crate::compress::state::LayerState;
use crate::tensor::LayerKind;
use crate::util::stats;

/// Training-regime switch (paper Alg. 2 `FullBatchGD` flag + τ).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SignMode {
    /// Full-batch gradient descent: oscillation flip.
    FullBatch,
    /// Mini-batch: kernel consistency threshold τ ∈ [0,1].
    MiniBatch { tau: f64 },
}

/// Sign-policy selector — the `sign=` key of the `CodecSpec` grammar.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SignSel {
    /// Regime-driven (the classic behavior): `full_batch` picks the
    /// oscillation flip, otherwise the kernel-consistency policy.
    #[default]
    Auto,
    /// Always the full-batch oscillation flip (Fig. 5).
    Osc,
    /// Always the kernel dominant-sign policy (Eq. 5 + Fig. 8 bitmap).
    Kernel,
    /// Sign prediction off (S = 0 everywhere, plain residual coding).
    None,
}

impl SignSel {
    /// All selectors, for registry-style sweeps.
    pub const ALL: [SignSel; 4] = [SignSel::Auto, SignSel::Osc, SignSel::Kernel, SignSel::None];

    /// Spec-grammar name (`sign=<name>`).
    pub fn name(&self) -> &'static str {
        match self {
            SignSel::Auto => "auto",
            SignSel::Osc => "osc",
            SignSel::Kernel => "kernel",
            SignSel::None => "none",
        }
    }

    /// Parse a spec-grammar name.
    pub fn from_name(s: &str) -> Option<SignSel> {
        match s {
            "auto" => Some(SignSel::Auto),
            "osc" => Some(SignSel::Osc),
            "kernel" => Some(SignSel::Kernel),
            "none" | "off" => Some(SignSel::None),
            _ => None,
        }
    }

    /// Resolve `Auto` against the training regime; fixed selectors pass
    /// through. Never returns `Auto`.
    pub fn effective(&self, full_batch: bool) -> SignSel {
        match self {
            SignSel::Auto => {
                if full_batch {
                    SignSel::Osc
                } else {
                    SignSel::Kernel
                }
            }
            s => *s,
        }
    }
}

/// One registry row per sign policy (mirrors the magnitude registry).
#[derive(Debug, Clone, Copy)]
pub struct SignFamily {
    pub name: &'static str,
    pub about: &'static str,
}

/// Every sign policy the `sign=` grammar accepts.
pub const SIGN_REGISTRY: &[SignFamily] = &[
    SignFamily { name: "auto", about: "full_batch → osc, otherwise kernel (the classic behavior)" },
    SignFamily { name: "osc", about: "oscillation flip (Fig. 5; 1 bit of side info per layer)" },
    SignFamily { name: "kernel", about: "kernel dominant sign via Eq. 5 + two-level bitmap" },
    SignFamily { name: "none", about: "sign prediction off (plain residual coding)" },
];

/// A pluggable sign policy. The client half fills the elementwise sign
/// tensor and produces the self-describing [`SignMeta`] side info; the
/// server half is meta-driven (identical across policies by design, so
/// the decoder needs zero out-of-band config) and therefore provided.
///
/// Implementations own which [`LayerState`] views they read: the
/// oscillation policy reads `prev_recon`/`prev_sign`, the kernel policy
/// reads nothing (current-round structure only).
pub trait SignPredictor: Send + Sync {
    /// Registry name (matches [`SignSel::name`] of the fixed selectors).
    fn name(&self) -> &'static str;

    /// Client side: fill `signs` (∈ {-1, 0, +1}, cleared and resized to
    /// the layer) and return the side info + stats.
    fn predict_into(
        &self,
        grad: &[f32],
        kind: &LayerKind,
        st: &LayerState,
        signs: &mut Vec<f32>,
    ) -> (SignMeta, SignStats);

    /// Server side: rebuild the exact sign tensor from side info +
    /// mirrored state. Meta-driven — the default is correct for every
    /// policy because [`SignMeta`] self-describes.
    fn reconstruct(
        &self,
        meta: &SignMeta,
        numel: usize,
        kind: &LayerKind,
        st: &LayerState,
    ) -> anyhow::Result<Vec<f32>> {
        reconstruct_signs(meta, numel, kind, st.prev_sign.as_deref())
    }
}

/// `sign=osc`: the full-batch oscillation flip.
#[derive(Debug, Clone, Copy)]
pub struct OscSign;

impl SignPredictor for OscSign {
    fn name(&self) -> &'static str {
        "osc"
    }

    fn predict_into(
        &self,
        grad: &[f32],
        kind: &LayerKind,
        st: &LayerState,
        signs: &mut Vec<f32>,
    ) -> (SignMeta, SignStats) {
        predict_signs_into(
            grad,
            kind,
            SignMode::FullBatch,
            st.prev_recon.as_deref(),
            st.prev_sign.as_deref(),
            signs,
        )
    }
}

/// `sign=kernel`: the mini-batch kernel dominant-sign policy.
#[derive(Debug, Clone, Copy)]
pub struct KernelSign {
    pub tau: f64,
}

impl SignPredictor for KernelSign {
    fn name(&self) -> &'static str {
        "kernel"
    }

    fn predict_into(
        &self,
        grad: &[f32],
        kind: &LayerKind,
        st: &LayerState,
        signs: &mut Vec<f32>,
    ) -> (SignMeta, SignStats) {
        predict_signs_into(
            grad,
            kind,
            SignMode::MiniBatch { tau: self.tau },
            st.prev_recon.as_deref(),
            st.prev_sign.as_deref(),
            signs,
        )
    }
}

/// `sign=none`: sign prediction off.
#[derive(Debug, Clone, Copy)]
pub struct NoSign;

impl SignPredictor for NoSign {
    fn name(&self) -> &'static str {
        "none"
    }

    fn predict_into(
        &self,
        grad: &[f32],
        _kind: &LayerKind,
        _st: &LayerState,
        signs: &mut Vec<f32>,
    ) -> (SignMeta, SignStats) {
        signs.clear();
        signs.resize(grad.len(), 0.0);
        (SignMeta::None, SignStats::default())
    }
}

/// Side information produced by the client-side predictor; travels in the
/// payload so the server reproduces the same sign tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum SignMeta {
    /// No sign prediction for this layer.
    None,
    /// Full-batch: whether to flip the previous sign tensor.
    Flip(bool),
    /// Mini-batch conv layer: the two-level kernel bitmap.
    Bitmap(KernelBitmap),
}

impl SignMeta {
    /// Serialize with a 1-byte tag.
    pub fn encode(&self) -> Vec<u8> {
        match self {
            SignMeta::None => vec![0],
            SignMeta::Flip(f) => vec![1, *f as u8],
            SignMeta::Bitmap(bm) => {
                let mut out = vec![2];
                out.extend_from_slice(&bm.encode());
                out
            }
        }
    }

    pub fn decode(buf: &[u8]) -> anyhow::Result<SignMeta> {
        Self::decode_bounded(buf, u32::MAX as usize)
    }

    /// [`Self::decode`] with a caller-known cap on the layer's element
    /// count. A kernel carries at least one element, so a bitmap's
    /// declared kernel count is bounded by `max_numel`; a corrupt stream
    /// declaring an inflated count is rejected **before** any allocation
    /// — the same untrusted-payload OOM guard as
    /// [`crate::compress::entropy::EntropyCoder::decode_bounded`].
    pub fn decode_bounded(buf: &[u8], max_numel: usize) -> anyhow::Result<SignMeta> {
        match buf.first() {
            Some(0) => Ok(SignMeta::None),
            Some(1) => Ok(SignMeta::Flip(
                *buf.get(1).ok_or_else(|| anyhow::anyhow!("flip underrun"))? != 0,
            )),
            Some(2) => Ok(SignMeta::Bitmap(KernelBitmap::decode_bounded(&buf[1..], max_numel)?)),
            _ => anyhow::bail!("bad sign meta"),
        }
    }
}

/// Statistics from one layer's sign prediction (for Table 5 / Fig. 7).
#[derive(Debug, Clone, Copy, Default)]
pub struct SignStats {
    pub kernels_total: usize,
    pub kernels_predicted: usize,
    /// Elements whose predicted sign disagreed with the true gradient sign
    /// (zeros in either excluded).
    pub sign_mismatches: usize,
    /// Elements carrying a predicted (nonzero) sign.
    pub elements_predicted: usize,
}

impl SignStats {
    pub fn prediction_ratio(&self) -> f64 {
        if self.kernels_total == 0 {
            0.0
        } else {
            self.kernels_predicted as f64 / self.kernels_total as f64
        }
    }
    pub fn mismatch_rate(&self) -> f64 {
        if self.elements_predicted == 0 {
            0.0
        } else {
            self.sign_mismatches as f64 / self.elements_predicted as f64
        }
    }
}

/// Client-side sign prediction (Alg. 2). Returns the elementwise sign
/// tensor `S ∈ {-1,0,+1}`, the side info for the server, and stats.
///
/// `prev_recon`/`prev_sign` are the previous round's reconstructed
/// gradient and sign tensor (None on round 1).
pub fn predict_signs(
    grad: &[f32],
    kind: &LayerKind,
    mode: SignMode,
    prev_recon: Option<&[f32]>,
    prev_sign: Option<&[f32]>,
) -> (Vec<f32>, SignMeta, SignStats) {
    let mut signs = Vec::new();
    let (meta, stats) = predict_signs_into(grad, kind, mode, prev_recon, prev_sign, &mut signs);
    (signs, meta, stats)
}

/// [`predict_signs`] into a caller-owned sign buffer (cleared and
/// resized to the layer) — the pipeline reuses one buffer per layer
/// slot across rounds instead of allocating per call.
pub fn predict_signs_into(
    grad: &[f32],
    kind: &LayerKind,
    mode: SignMode,
    prev_recon: Option<&[f32]>,
    prev_sign: Option<&[f32]>,
    signs: &mut Vec<f32>,
) -> (SignMeta, SignStats) {
    signs.clear();
    match mode {
        SignMode::FullBatch => {
            let (Some(prev), Some(psign)) = (prev_recon, prev_sign) else {
                signs.resize(grad.len(), 0.0);
                return (SignMeta::Flip(false), SignStats::default());
            };
            let c = stats::gradient_correlation(prev, grad);
            let flip = c < 0.0;
            let f = if flip { -1.0 } else { 1.0 };
            signs.extend(psign.iter().map(|&s| f * s));
            let stats = mismatch_stats(signs, grad, 0, 0);
            (SignMeta::Flip(flip), stats)
        }
        SignMode::MiniBatch { tau } => {
            signs.resize(grad.len(), 0.0);
            let Some(t) = kind.kernel_size() else {
                // Non-conv layer: no structural sign prediction.
                return (SignMeta::None, SignStats::default());
            };
            let n_kernels = grad.len() / t;
            let mut decisions = Vec::with_capacity(n_kernels);
            let mut predicted = 0usize;
            // Single pass per kernel: P/N/Z counts give both the Eq. 5
            // consistency and the dominant sign (hot path, §Perf).
            let half = t.div_ceil(2);
            let den = (t - half).max(1) as f64;
            for k in 0..n_kernels {
                let kernel = &grad[k * t..(k + 1) * t];
                let (mut p, mut n) = (0usize, 0usize);
                for &x in kernel {
                    p += (x > 0.0) as usize;
                    n += (x < 0.0) as usize;
                }
                let z = t - p - n;
                let consistency = (((p.max(n) + z) as f64 - half as f64) / den).clamp(0.0, 1.0);
                let ok = if t <= 1 { true } else { consistency >= tau };
                if ok {
                    let s = if p > n { 1.0f32 } else { -1.0 };
                    decisions.push(Some(s > 0.0));
                    signs[k * t..(k + 1) * t].fill(s);
                    predicted += 1;
                } else {
                    decisions.push(None);
                }
            }
            let meta = SignMeta::Bitmap(KernelBitmap::from_decisions(&decisions));
            let stats = mismatch_stats(signs, grad, n_kernels, predicted);
            (meta, stats)
        }
    }
}

/// Server-side sign reconstruction (Alg. 4 line 11): rebuild the exact
/// same sign tensor from the side info + mirrored state.
pub fn reconstruct_signs(
    meta: &SignMeta,
    numel: usize,
    kind: &LayerKind,
    prev_sign: Option<&[f32]>,
) -> anyhow::Result<Vec<f32>> {
    match meta {
        SignMeta::None => Ok(vec![0.0; numel]),
        SignMeta::Flip(flip) => {
            let Some(psign) = prev_sign else {
                return Ok(vec![0.0; numel]);
            };
            let f = if *flip { -1.0 } else { 1.0 };
            Ok(psign.iter().map(|&s| f * s).collect())
        }
        SignMeta::Bitmap(bm) => {
            let t = kind
                .kernel_size()
                .ok_or_else(|| anyhow::anyhow!("bitmap sign meta on non-conv layer"))?;
            if bm.predicted.len() * t != numel {
                anyhow::bail!(
                    "bitmap kernel count {} x {} != numel {}",
                    bm.predicted.len(),
                    t,
                    numel
                );
            }
            let mut signs = vec![0.0f32; numel];
            let mut sign_bits = bm.signs.iter();
            for (k, &p) in bm.predicted.iter().enumerate() {
                if p {
                    let s = if *sign_bits.next().expect("sign bit") { 1.0 } else { -1.0 };
                    signs[k * t..(k + 1) * t].fill(s);
                }
            }
            Ok(signs)
        }
    }
}

fn mismatch_stats(signs: &[f32], grad: &[f32], kernels_total: usize, kernels_predicted: usize) -> SignStats {
    let mut st = SignStats { kernels_total, kernels_predicted, ..Default::default() };
    for (&s, &g) in signs.iter().zip(grad) {
        if s != 0.0 {
            st.elements_predicted += 1;
            if g != 0.0 && (s > 0.0) != (g > 0.0) {
                st.sign_mismatches += 1;
            }
        }
    }
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::LayerKind;
    use crate::util::rng::Rng;

    fn conv(kernels: usize, t: usize) -> LayerKind {
        LayerKind::Conv { out_ch: kernels, in_ch: 1, kh: 1, kw: t }
    }

    #[test]
    fn minibatch_consistent_kernel_predicted() {
        // One fully-positive kernel, one mixed kernel (consistency 0).
        let grad = vec![1.0f32, 2.0, 3.0, 1.0, -1.0, 1.0, -1.0, 1.0];
        let kind = LayerKind::Conv { out_ch: 2, in_ch: 1, kh: 2, kw: 2 };
        let (signs, meta, st) = predict_signs(&grad, &kind, SignMode::MiniBatch { tau: 0.9 }, None, None);
        assert_eq!(&signs[..4], &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(&signs[4..], &[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(st.kernels_predicted, 1);
        assert_eq!(st.kernels_total, 2);
        match meta {
            SignMeta::Bitmap(bm) => assert_eq!(bm.decisions(), vec![Some(true), None]),
            _ => panic!("expected bitmap"),
        }
    }

    #[test]
    fn minibatch_non_conv_no_prediction() {
        let grad = vec![1.0f32; 10];
        let (signs, meta, _) =
            predict_signs(&grad, &LayerKind::Other, SignMode::MiniBatch { tau: 0.5 }, None, None);
        assert!(signs.iter().all(|&s| s == 0.0));
        assert_eq!(meta, SignMeta::None);
    }

    #[test]
    fn fullbatch_flip_on_anticorrelation() {
        let prev = vec![1.0f32, -2.0, 3.0];
        let psign = vec![1.0f32, -1.0, 1.0];
        let grad = vec![-1.0f32, 2.0, -3.0]; // perfectly anti-correlated
        let (signs, meta, _) =
            predict_signs(&grad, &LayerKind::Other, SignMode::FullBatch, Some(&prev), Some(&psign));
        assert_eq!(meta, SignMeta::Flip(true));
        assert_eq!(signs, vec![-1.0, 1.0, -1.0]);
    }

    #[test]
    fn fullbatch_keep_on_correlation() {
        let prev = vec![1.0f32, -2.0, 3.0];
        let psign = vec![1.0f32, -1.0, 1.0];
        let (signs, meta, _) =
            predict_signs(&prev.clone(), &LayerKind::Other, SignMode::FullBatch, Some(&prev), Some(&psign));
        assert_eq!(meta, SignMeta::Flip(false));
        assert_eq!(signs, psign);
    }

    #[test]
    fn server_reconstruction_matches_client() {
        let mut rng = Rng::new(17);
        let t = 9;
        let n_kernels = 64;
        // Kernels with strong dominant-sign structure.
        let mut grad = Vec::with_capacity(n_kernels * t);
        for _ in 0..n_kernels {
            let dom: f32 = if rng.chance(0.5) { 1.0 } else { -1.0 };
            for _ in 0..t {
                let flip = rng.chance(0.15);
                grad.push(dom * if flip { -1.0 } else { 1.0 } * (0.1 + rng.next_f32()));
            }
        }
        let kind = conv(n_kernels, t);
        let (signs, meta, _) =
            predict_signs(&grad, &kind, SignMode::MiniBatch { tau: 0.5 }, None, None);
        // Roundtrip meta through bytes like the payload does.
        let decoded = SignMeta::decode(&meta.encode()).unwrap();
        let recon = reconstruct_signs(&decoded, grad.len(), &kind, None).unwrap();
        assert_eq!(signs, recon);
    }

    #[test]
    fn sign_meta_encode_roundtrip() {
        for meta in [
            SignMeta::None,
            SignMeta::Flip(true),
            SignMeta::Flip(false),
            SignMeta::Bitmap(KernelBitmap::from_decisions(&[Some(true), None, Some(false)])),
        ] {
            assert_eq!(SignMeta::decode(&meta.encode()).unwrap(), meta);
        }
    }

    #[test]
    fn bitmap_size_mismatch_errors() {
        let bm = KernelBitmap::from_decisions(&[Some(true); 4]);
        let err = reconstruct_signs(&SignMeta::Bitmap(bm), 100, &conv(4, 9), None);
        assert!(err.is_err());
    }

    #[test]
    fn decode_bounded_rejects_oversize_and_truncation() {
        // A corrupt bitmap stream declaring more kernels than the layer
        // has elements must error before allocating (OOM guard) — both
        // via an inflated header and via a header larger than the bits
        // actually present.
        let bm = KernelBitmap::from_decisions(&[Some(true), None, Some(false), None]);
        let encoded = SignMeta::Bitmap(bm.clone()).encode();
        // Well-formed stream decodes under a matching bound...
        assert_eq!(SignMeta::decode_bounded(&encoded, 4).unwrap(), SignMeta::Bitmap(bm));
        // ...but an undersized numel bound rejects it.
        assert!(SignMeta::decode_bounded(&encoded, 3).is_err());
        // Adversarial header: 2^31 declared kernels in a 6-byte buffer.
        let mut evil = vec![2u8];
        evil.extend_from_slice(&(1u32 << 31).to_le_bytes());
        evil.push(0xFF);
        assert!(SignMeta::decode_bounded(&evil, 1 << 20).is_err());
        assert!(SignMeta::decode(&evil).is_err(), "plausibility guard covers unbounded decode");
        // Truncations at every prefix error, never panic.
        for cut in 0..encoded.len() {
            let _ = SignMeta::decode_bounded(&encoded[..cut], 4);
        }
        assert!(SignMeta::decode_bounded(&encoded[..1], 4).is_err());
    }

    #[test]
    fn sign_selector_names_roundtrip_and_resolve() {
        for sel in SignSel::ALL {
            assert_eq!(SignSel::from_name(sel.name()), Some(sel));
        }
        assert_eq!(SignSel::from_name("bogus"), None);
        assert_eq!(SignSel::default(), SignSel::Auto);
        assert_eq!(SignSel::Auto.effective(true), SignSel::Osc);
        assert_eq!(SignSel::Auto.effective(false), SignSel::Kernel);
        for sel in [SignSel::Osc, SignSel::Kernel, SignSel::None] {
            assert_eq!(sel.effective(true), sel);
            assert_eq!(sel.effective(false), sel);
            assert_ne!(sel.effective(false), SignSel::Auto);
        }
        for fam in SIGN_REGISTRY {
            assert!(SignSel::from_name(fam.name).is_some(), "{}", fam.name);
        }
    }

    #[test]
    fn trait_impls_match_free_functions() {
        use crate::compress::state::LayerState;
        let mut rng = Rng::new(23);
        let t = 9;
        let grad: Vec<f32> = (0..t * 8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let kind = conv(8, t);
        let mut st = LayerState::default();
        st.absorb(&grad.iter().map(|x| -x).collect::<Vec<_>>());
        let mut signs = Vec::new();

        let (meta, _) = KernelSign { tau: 0.5 }.predict_into(&grad, &kind, &st, &mut signs);
        let (want_signs, want_meta, _) = predict_signs(
            &grad,
            &kind,
            SignMode::MiniBatch { tau: 0.5 },
            st.prev_recon.as_deref(),
            st.prev_sign.as_deref(),
        );
        assert_eq!(signs, want_signs);
        assert_eq!(meta, want_meta);
        // Trait-provided reconstruct == free function (meta-driven).
        let via_trait = KernelSign { tau: 0.5 }.reconstruct(&meta, grad.len(), &kind, &st).unwrap();
        assert_eq!(via_trait, signs);

        let (meta, _) = OscSign.predict_into(&grad, &kind, &st, &mut signs);
        assert_eq!(meta, SignMeta::Flip(true), "anti-correlated history flips");
        assert_eq!(OscSign.reconstruct(&meta, grad.len(), &kind, &st).unwrap(), signs);

        let (meta, stats) = NoSign.predict_into(&grad, &kind, &st, &mut signs);
        assert_eq!(meta, SignMeta::None);
        assert_eq!(stats.elements_predicted, 0);
        assert!(signs.iter().all(|&s| s == 0.0) && signs.len() == grad.len());
    }

    #[test]
    fn tau_one_only_perfect_kernels() {
        let grad = vec![1.0f32, 1.0, 1.0, 1.0, 1.0, -1.0];
        let kind = LayerKind::Conv { out_ch: 2, in_ch: 1, kh: 1, kw: 3 };
        let (_, meta, st) = predict_signs(&grad, &kind, SignMode::MiniBatch { tau: 1.0 }, None, None);
        assert_eq!(st.kernels_predicted, 1);
        match meta {
            SignMeta::Bitmap(bm) => assert_eq!(bm.decisions(), vec![Some(true), None]),
            _ => panic!(),
        }
    }
}
