//! Gradient **magnitude** predictor (paper Alg. 1).
//!
//! At epoch *t* the predictor sees only the *reconstructed* absolute
//! gradient of the previous round `ã^(t-1)` (so client and server stay in
//! lock-step) plus the current round's scalar statistics
//! `(μ_curr, σ_curr)`, which travel in the payload:
//!
//! ```text
//! z       = (ã^(t-1) − mean(ã^(t-1))) / std(ã^(t-1))     per-epoch normalize
//! m'      = β·m + (1−β)·z                                EMA in normalized space
//! â^(t)   = max(0, m'·σ_curr + μ_curr)                   de-normalize
//! ```
//!
//! Normalization is what lets a single EMA track non-stationary gradient
//! scales across epochs and layers (paper §4.2). The same math is
//! implemented by the L1 Pallas kernel; [`crate::compress::fused`] keeps
//! the two bit-identical by sharing scalar pre-computation.
//!
//! This module also implements the Table-1 ablation variants (Lorenzo,
//! MA(3)/MA(5), AR(1), EMA without normalization).

use crate::util::stats;

/// Numerical floor for σ to avoid division blow-ups on constant tensors.
pub const SIGMA_EPS: f32 = 1e-12;

/// The production predictor: normalized EMA with per-layer memory.
#[derive(Debug, Clone)]
pub struct EmaNormPredictor {
    /// Decay factor β in `m' = β·m + (1−β)·z`.
    pub beta: f32,
    /// Memory tensor `m`, same shape as the layer. `None` until round 2.
    pub memory: Option<Vec<f32>>,
}

impl EmaNormPredictor {
    pub fn new(beta: f32) -> Self {
        EmaNormPredictor { beta, memory: None }
    }

    /// Predict the current magnitude tensor and update the memory.
    ///
    /// `prev_abs` is `|g̃^(t-1)|`; `mu_curr`/`sigma_curr` are the scalar
    /// stats of the *current* absolute gradient (transmitted in the
    /// payload). Returns zeros on the first round (no history yet — the
    /// pipeline treats â=0 as "no prediction").
    pub fn predict(&mut self, prev_abs: Option<&[f32]>, mu_curr: f32, sigma_curr: f32) -> Vec<f32> {
        let prev_abs = match prev_abs {
            Some(p) => p,
            None => return Vec::new(), // round 1: no prediction
        };
        let n = prev_abs.len();
        let (mu_prev, sigma_prev) = stats::mean_std(prev_abs);
        let inv_sigma_prev = 1.0 / sigma_prev.max(SIGMA_EPS);
        if self.memory.is_none() {
            self.memory = Some(vec![0.0; n]);
        }
        let m = self.memory.as_mut().unwrap();
        assert_eq!(m.len(), n, "layer size changed between rounds");
        let mut out = Vec::with_capacity(n);
        let beta = self.beta;
        for i in 0..n {
            let z = (prev_abs[i] - mu_prev) * inv_sigma_prev;
            let mi = beta * m[i] + (1.0 - beta) * z;
            m[i] = mi;
            out.push((mi * sigma_curr + mu_curr).max(0.0));
        }
        out
    }

    pub fn reset(&mut self) {
        self.memory = None;
    }
}

/// Ablation variants of Table 1. All predict the current magnitude tensor
/// from history of (reconstructed) magnitude tensors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MagnitudeVariant {
    /// Lorenzo in time: â^(t) = ã^(t-1).
    Lorenzo,
    /// Moving average over a sliding window of w rounds.
    MovingAverage(usize),
    /// First-order autoregressive model with online-estimated φ.
    Ar1,
    /// EMA directly on raw magnitudes (no normalization).
    EmaNoNorm,
    /// The production predictor (Alg. 1).
    EmaNorm,
}

impl MagnitudeVariant {
    pub fn name(&self) -> String {
        match self {
            MagnitudeVariant::Lorenzo => "Lorenzo".into(),
            MagnitudeVariant::MovingAverage(w) => format!("MA (w={w})"),
            MagnitudeVariant::Ar1 => "AR(1)".into(),
            MagnitudeVariant::EmaNoNorm => "EMA (No Norm)".into(),
            MagnitudeVariant::EmaNorm => "EMA (Norm)".into(),
        }
    }
}

/// Stateful runner for any [`MagnitudeVariant`] — used by the Table-1
/// ablation bench. Feed the *true* magnitude tensors round by round;
/// `step` returns the prediction made *before* seeing the new tensor.
pub struct VariantRunner {
    variant: MagnitudeVariant,
    beta: f32,
    history: Vec<Vec<f32>>,
    ema_norm: EmaNormPredictor,
    ema_raw: Option<Vec<f32>>,
    /// Online AR(1) sufficient statistics (lag-1 cross/auto products).
    ar_num: f64,
    ar_den: f64,
}

impl VariantRunner {
    pub fn new(variant: MagnitudeVariant, beta: f32) -> Self {
        VariantRunner {
            variant,
            beta,
            history: Vec::new(),
            ema_norm: EmaNormPredictor::new(beta),
            ema_raw: None,
            ar_num: 0.0,
            ar_den: 0.0,
        }
    }

    /// Predict the magnitude tensor for this round, then absorb the truth.
    pub fn step(&mut self, truth_abs: &[f32]) -> Vec<f32> {
        let n = truth_abs.len();
        let pred = match self.variant {
            MagnitudeVariant::Lorenzo => {
                self.history.last().cloned().unwrap_or_else(|| vec![0.0; n])
            }
            MagnitudeVariant::MovingAverage(w) => {
                if self.history.is_empty() {
                    vec![0.0; n]
                } else {
                    let take = self.history.len().min(w);
                    let slice = &self.history[self.history.len() - take..];
                    let mut out = vec![0.0f32; n];
                    for h in slice {
                        for i in 0..n {
                            out[i] += h[i];
                        }
                    }
                    for v in &mut out {
                        *v /= take as f32;
                    }
                    out
                }
            }
            MagnitudeVariant::Ar1 => {
                let phi = if self.ar_den > 0.0 {
                    (self.ar_num / self.ar_den).clamp(-1.0, 1.0) as f32
                } else {
                    1.0
                };
                match self.history.last() {
                    Some(prev) => prev.iter().map(|&x| phi * x).collect(),
                    None => vec![0.0; n],
                }
            }
            MagnitudeVariant::EmaNoNorm => match &self.ema_raw {
                Some(m) => m.clone(),
                None => vec![0.0; n],
            },
            MagnitudeVariant::EmaNorm => {
                let (mu, sigma) = stats::mean_std(truth_abs);
                let prev = self.history.last().map(|v| v.as_slice());
                let p = self.ema_norm.predict(prev, mu, sigma);
                if p.is_empty() {
                    vec![0.0; n]
                } else {
                    p
                }
            }
        };
        // Absorb truth into state.
        if let Some(prev) = self.history.last() {
            for i in 0..n {
                self.ar_num += (prev[i] as f64) * (truth_abs[i] as f64);
                self.ar_den += (prev[i] as f64) * (prev[i] as f64);
            }
        }
        match &mut self.ema_raw {
            Some(m) => {
                for i in 0..n {
                    m[i] = self.beta * m[i] + (1.0 - self.beta) * truth_abs[i];
                }
            }
            None => self.ema_raw = Some(truth_abs.to_vec()),
        }
        self.history.push(truth_abs.to_vec());
        if self.history.len() > 8 {
            self.history.remove(0);
        }
        pred
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn first_round_no_prediction() {
        let mut p = EmaNormPredictor::new(0.9);
        assert!(p.predict(None, 1.0, 0.5).is_empty());
    }

    #[test]
    fn prediction_is_nonnegative() {
        let mut p = EmaNormPredictor::new(0.5);
        let prev = vec![0.1f32, 0.9, 0.0, 0.4];
        let out = p.predict(Some(&prev), 0.01, 0.5);
        assert!(out.iter().all(|&x| x >= 0.0));
        assert_eq!(out.len(), prev.len());
    }

    #[test]
    fn memory_update_matches_formula() {
        let mut p = EmaNormPredictor::new(0.8);
        let prev = vec![1.0f32, 2.0, 3.0, 4.0];
        let (mu_p, sg_p) = stats::mean_std(&prev);
        let out = p.predict(Some(&prev), 10.0, 2.0);
        for i in 0..prev.len() {
            let z = (prev[i] - mu_p) / sg_p.max(SIGMA_EPS);
            let m = 0.2 * z; // memory starts at 0
            let want = (m * 2.0 + 10.0).max(0.0);
            assert!((out[i] - want).abs() < 1e-6, "i={i} out={} want={want}", out[i]);
        }
    }

    #[test]
    fn stationary_signal_converges() {
        // For a constant normalized pattern, prediction should converge to it.
        let mut p = EmaNormPredictor::new(0.5);
        let pattern = vec![0.1f32, 0.2, 0.3, 0.8];
        let (mu, sg) = stats::mean_std(&pattern);
        let mut pred = Vec::new();
        for _ in 0..30 {
            pred = p.predict(Some(&pattern), mu, sg);
        }
        for (a, b) in pred.iter().zip(&pattern) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn constant_tensor_does_not_nan() {
        let mut p = EmaNormPredictor::new(0.9);
        let prev = vec![0.5f32; 16];
        let out = p.predict(Some(&prev), 0.5, 0.0);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    /// The Table-1 ordering: on synthetic magnitude sequences with
    /// non-stationary scale + stable normalized pattern, EMA(Norm) must
    /// beat Lorenzo and EMA(NoNorm) on MSE — the paper's qualitative
    /// claim.
    #[test]
    fn ema_norm_wins_on_nonstationary_sequences() {
        let mut rng = Rng::new(42);
        let n = 256;
        let pattern: Vec<f32> = (0..n).map(|_| rng.uniform(0.2, 1.0) as f32).collect();
        let rounds = 60;
        let mut runners = [
            VariantRunner::new(MagnitudeVariant::Lorenzo, 0.9),
            VariantRunner::new(MagnitudeVariant::EmaNoNorm, 0.9),
            VariantRunner::new(MagnitudeVariant::EmaNorm, 0.9),
        ];
        let mut errs = [0.0f64; 3];
        for t in 0..rounds {
            // Scale decays like real training, with strong round-to-round
            // jitter — the non-stationarity that per-epoch normalization
            // corrects (the EMA tracks a stationary pattern in z-space,
            // while Lorenzo/EMA-raw absorb the full scale error).
            let jitter = (0.35 * rng.gauss()).exp();
            let scale = (jitter / (1.0 + 0.2 * t as f64)) as f32;
            let truth: Vec<f32> = pattern
                .iter()
                .map(|&p| (p * scale * (1.0 + 0.1 * rng.gauss() as f32)).abs())
                .collect();
            for (k, r) in runners.iter_mut().enumerate() {
                let pred = r.step(&truth);
                if t > 3 {
                    errs[k] += stats::mse(&pred, &truth);
                }
            }
        }
        assert!(errs[2] < errs[0], "EMA(Norm) {} vs Lorenzo {}", errs[2], errs[0]);
        assert!(errs[2] < errs[1], "EMA(Norm) {} vs EMA(NoNorm) {}", errs[2], errs[1]);
    }
}
