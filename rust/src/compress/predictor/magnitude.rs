//! Gradient **magnitude** predictor (paper Alg. 1).
//!
//! At epoch *t* the predictor sees only the *reconstructed* absolute
//! gradient of the previous round `ã^(t-1)` (so client and server stay in
//! lock-step) plus the current round's scalar statistics
//! `(μ_curr, σ_curr)`, which travel in the payload:
//!
//! ```text
//! z       = (ã^(t-1) − mean(ã^(t-1))) / std(ã^(t-1))     per-epoch normalize
//! m'      = β·m + (1−β)·z                                EMA in normalized space
//! â^(t)   = max(0, m'·σ_curr + μ_curr)                   de-normalize
//! ```
//!
//! Normalization is what lets a single EMA track non-stationary gradient
//! scales across epochs and layers (paper §4.2). The same math is
//! implemented by the L1 Pallas kernel; [`crate::compress::fused`] keeps
//! the two bit-identical by sharing scalar pre-computation.
//!
//! This module also implements the Table-1 ablation variants (Lorenzo,
//! MA(3)/MA(5), AR(1), EMA without normalization).

use crate::compress::state::LayerState;
use crate::util::stats;

/// Numerical floor for σ to avoid division blow-ups on constant tensors.
pub const SIGMA_EPS: f32 = 1e-12;

/// Default EMA decay β — the single source of truth shared by
/// [`crate::compress::pipeline::FedgecConfig::default`],
/// [`crate::compress::spec::SpecDefaults::default`] and the `pred=ema`
/// grammar default, so the struct and grammar defaults can never drift
/// apart (asserted by the spec tests).
pub const DEFAULT_BETA: f32 = 0.9;

/// One scalar step of the normalized-EMA predictor (Alg. 1): updates the
/// memory cell in place and returns â. This is the single implementation
/// shared by [`EmaNormPredictor`], [`EmaPredictor`] and the fused kernel
/// ([`crate::compress::fused`]) — identical f32 operation order is what
/// keeps the three bit-identical.
#[inline]
pub fn ema_norm_step(
    beta: f32,
    m: &mut f32,
    prev_abs: f32,
    mu_prev: f32,
    inv_sigma_prev: f32,
    mu_curr: f32,
    sigma_curr: f32,
) -> f32 {
    let z = (prev_abs - mu_prev) * inv_sigma_prev;
    let mi = beta * *m + (1.0 - beta) * z;
    *m = mi;
    (mi * sigma_curr + mu_curr).max(0.0)
}

/// In-place raw EMA update `m ← β·m + (1−β)·x` (the no-normalization
/// ablation's memory rule — shared so the math exists once).
pub fn ema_update(m: &mut [f32], x: &[f32], beta: f32) {
    for (mi, &xi) in m.iter_mut().zip(x) {
        *mi = beta * *mi + (1.0 - beta) * xi;
    }
}

// ───────────────────── pluggable predictor API ─────────────────────

/// Magnitude-predictor selector — the `pred=` key of the `CodecSpec`
/// grammar. The first three name fixed predictors; `Auto` is a racing
/// policy that picks one of them per layer per round by measured bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MagnitudeSel {
    /// Normalized cross-round EMA (Alg. 1); β from `beta=`/`pred=ema:<β>`.
    #[default]
    Ema,
    /// Lorenzo-in-time: â = |g̃^(t-1)|.
    Last,
    /// No magnitude prediction (plain SZ-style residual against 0).
    Zero,
    /// Race ema/last/zero per layer each round, keep the cheapest.
    Auto,
}

impl MagnitudeSel {
    /// All selectors, for registry-style sweeps.
    pub const ALL: [MagnitudeSel; 4] =
        [MagnitudeSel::Ema, MagnitudeSel::Last, MagnitudeSel::Zero, MagnitudeSel::Auto];

    /// Spec-grammar name (`pred=<name>`).
    pub fn name(&self) -> &'static str {
        match self {
            MagnitudeSel::Ema => "ema",
            MagnitudeSel::Last => "last",
            MagnitudeSel::Zero => "zero",
            MagnitudeSel::Auto => "auto",
        }
    }

    /// Parse a spec-grammar name (the `ema:<beta>` form is handled by
    /// the spec parser, which strips the suffix first).
    pub fn from_name(s: &str) -> Option<MagnitudeSel> {
        match s {
            "ema" => Some(MagnitudeSel::Ema),
            "last" | "lorenzo" => Some(MagnitudeSel::Last),
            "zero" | "none" => Some(MagnitudeSel::Zero),
            "auto" => Some(MagnitudeSel::Auto),
            _ => None,
        }
    }

    /// Tag folded into [`LayerState`] fingerprints and `FGS3` spill
    /// records, so state written under one predictor config can never be
    /// mistaken for another's across evict→reload or the `StateCheck`
    /// handshake.
    pub fn state_tag(&self) -> u8 {
        match self {
            MagnitudeSel::Ema => 0,
            MagnitudeSel::Last => 1,
            MagnitudeSel::Zero => 2,
            MagnitudeSel::Auto => 3,
        }
    }
}

/// Wire tag of the magnitude predictor that actually produced one layer
/// frame, recorded in self-describing (v3) layer sections so the decoder
/// reconstructs with zero out-of-band config. `auto` is a racing policy,
/// not a wire tag — its frames record the per-round winner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredTag {
    Ema,
    Last,
    Zero,
}

impl PredTag {
    /// All wire tags, for registry-style sweeps.
    pub const ALL: [PredTag; 3] = [PredTag::Ema, PredTag::Last, PredTag::Zero];

    /// Byte recorded in v3 layer sections ([`crate::compress::blob`]).
    pub fn tag(&self) -> u8 {
        match self {
            PredTag::Ema => 0,
            PredTag::Last => 1,
            PredTag::Zero => 2,
        }
    }

    /// Inverse of [`Self::tag`].
    pub fn from_tag(t: u8) -> anyhow::Result<PredTag> {
        match t {
            0 => Ok(PredTag::Ema),
            1 => Ok(PredTag::Last),
            2 => Ok(PredTag::Zero),
            _ => anyhow::bail!("unknown predictor tag {t}"),
        }
    }

    /// Report/diagnostic name (matches the fixed selector names).
    pub fn name(&self) -> &'static str {
        match self {
            PredTag::Ema => "ema",
            PredTag::Last => "last",
            PredTag::Zero => "zero",
        }
    }
}

/// One registry row per magnitude predictor (mirrors
/// [`crate::compress::spec::REGISTRY`] / `EntropyCoder::ALL`).
#[derive(Debug, Clone, Copy)]
pub struct MagFamily {
    pub name: &'static str,
    pub about: &'static str,
}

/// Every magnitude predictor the `pred=` grammar accepts.
pub const MAG_REGISTRY: &[MagFamily] = &[
    MagFamily { name: "ema", about: "normalized cross-round EMA (Alg. 1); β via beta=/pred=ema:<β>" },
    MagFamily { name: "last", about: "Lorenzo-in-time: â = |g̃^(t-1)|" },
    MagFamily { name: "zero", about: "no magnitude prediction (plain SZ-style residual)" },
    MagFamily { name: "auto", about: "race ema/last/zero per layer per round, keep the cheapest" },
];

/// Scalar plan of one magnitude-prediction round: the stats of the
/// previous reconstructed magnitudes. Both sides recompute identical
/// values from the mirrored state — nothing is transmitted.
#[derive(Debug, Clone, Copy, Default)]
pub struct MagPlan {
    pub mu_prev: f32,
    pub sigma_prev: f32,
}

impl MagPlan {
    /// Plan from `|g̃^(t-1)|` (`None` on round 1 ⇒ zeros).
    pub fn of(prev_abs: Option<&[f32]>) -> MagPlan {
        match prev_abs {
            Some(p) => {
                let (mu_prev, sigma_prev) = stats::mean_std(p);
                MagPlan { mu_prev, sigma_prev }
            }
            None => MagPlan::default(),
        }
    }
}

/// A pluggable magnitude predictor: **plan** (scalar stats from the
/// mirrored history) → **predict** (elementwise â, updating the
/// predictor-owned per-layer memory) → **absorb** (fold this round's
/// reconstruction back into the state views it reads next round).
///
/// Implementations own which [`LayerState`] views they touch: EMA owns
/// the `memory` tensor; every predictor consumes the `|g̃^(t-1)|`
/// history that the shared [`LayerState::absorb`] maintains.
pub trait MagnitudePredictor: Send + Sync {
    /// Wire tag recorded in self-describing (v3) layer frames.
    fn tag(&self) -> PredTag;

    /// Plan: scalar statistics derived from the previous reconstructed
    /// magnitudes (identical on both sides by the mirror invariant).
    fn plan(&self, prev_abs: Option<&[f32]>) -> MagPlan {
        MagPlan::of(prev_abs)
    }

    /// Predict â^(t) into `out` (cleared and filled to `n`). `memory` is
    /// the predictor-owned per-layer memory: EMA updates it in place
    /// (resizing to `n` zeros on first use or shape change, exactly like
    /// the fused kernel); the other predictors leave it untouched. Round
    /// 1 (`prev_abs == None`) predicts all-zero without touching memory.
    fn predict_into(
        &self,
        plan: &MagPlan,
        prev_abs: Option<&[f32]>,
        memory: &mut Vec<f32>,
        mu_curr: f32,
        sigma_curr: f32,
        n: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()>;

    /// Absorb this round's reconstruction into the cross-round views.
    fn absorb(&self, st: &mut LayerState, recon: &[f32]) {
        st.absorb(recon);
    }
}

/// The production predictor behind `pred=ema`: normalized cross-round
/// EMA (Alg. 1), memory-owning.
#[derive(Debug, Clone, Copy)]
pub struct EmaPredictor {
    pub beta: f32,
}

impl MagnitudePredictor for EmaPredictor {
    fn tag(&self) -> PredTag {
        PredTag::Ema
    }

    fn predict_into(
        &self,
        plan: &MagPlan,
        prev_abs: Option<&[f32]>,
        memory: &mut Vec<f32>,
        mu_curr: f32,
        sigma_curr: f32,
        n: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        out.clear();
        let Some(prev) = prev_abs else {
            out.resize(n, 0.0);
            return Ok(());
        };
        anyhow::ensure!(prev.len() == n, "ema predictor: prev len {} != {n}", prev.len());
        if memory.len() != n {
            memory.clear();
            memory.resize(n, 0.0);
        }
        let inv_sigma_prev = 1.0 / plan.sigma_prev.max(SIGMA_EPS);
        out.reserve(n);
        for (&pa, m) in prev.iter().zip(memory.iter_mut()) {
            out.push(ema_norm_step(
                self.beta,
                m,
                pa,
                plan.mu_prev,
                inv_sigma_prev,
                mu_curr,
                sigma_curr,
            ));
        }
        Ok(())
    }
}

/// `pred=last`: Lorenzo in time — â^(t) = |g̃^(t-1)|, no memory.
#[derive(Debug, Clone, Copy)]
pub struct LastPredictor;

impl MagnitudePredictor for LastPredictor {
    fn tag(&self) -> PredTag {
        PredTag::Last
    }

    /// Lorenzo needs no scalar stats — skip the O(n) plan pass.
    fn plan(&self, _prev_abs: Option<&[f32]>) -> MagPlan {
        MagPlan::default()
    }

    fn predict_into(
        &self,
        _plan: &MagPlan,
        prev_abs: Option<&[f32]>,
        _memory: &mut Vec<f32>,
        _mu_curr: f32,
        _sigma_curr: f32,
        n: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        out.clear();
        match prev_abs {
            None => out.resize(n, 0.0),
            Some(p) => {
                anyhow::ensure!(p.len() == n, "last predictor: prev len {} != {n}", p.len());
                out.extend_from_slice(p);
            }
        }
        Ok(())
    }
}

/// `pred=zero`: no magnitude prediction — the plain SZ-style residual.
#[derive(Debug, Clone, Copy)]
pub struct ZeroPredictor;

impl MagnitudePredictor for ZeroPredictor {
    fn tag(&self) -> PredTag {
        PredTag::Zero
    }

    /// No prediction ⇒ no plan — skip the O(n) stats pass.
    fn plan(&self, _prev_abs: Option<&[f32]>) -> MagPlan {
        MagPlan::default()
    }

    fn predict_into(
        &self,
        _plan: &MagPlan,
        _prev_abs: Option<&[f32]>,
        _memory: &mut Vec<f32>,
        _mu_curr: f32,
        _sigma_curr: f32,
        n: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        out.clear();
        out.resize(n, 0.0);
        Ok(())
    }
}

/// Run `f` against the [`MagnitudePredictor`] implementation a wire tag
/// names (`beta` feeds the EMA instance only) — the registry dispatch
/// point the pipeline goes through, so the trait methods are the
/// production code path, not parallel API surface.
pub fn with_tag_impl<R>(tag: PredTag, beta: f32, f: impl FnOnce(&dyn MagnitudePredictor) -> R) -> R {
    match tag {
        PredTag::Ema => f(&EmaPredictor { beta }),
        PredTag::Last => f(&LastPredictor),
        PredTag::Zero => f(&ZeroPredictor),
    }
}

/// Plan + predict with the implementation a wire tag names — the decode
/// half of self-describing frames, and the race's candidate evaluator.
pub fn predict_with_tag(
    tag: PredTag,
    beta: f32,
    prev_abs: Option<&[f32]>,
    memory: &mut Vec<f32>,
    mu_curr: f32,
    sigma_curr: f32,
    n: usize,
    out: &mut Vec<f32>,
) -> anyhow::Result<()> {
    with_tag_impl(tag, beta, |p| {
        let plan = p.plan(prev_abs);
        p.predict_into(&plan, prev_abs, memory, mu_curr, sigma_curr, n, out)
    })
}

/// Absorb a round's reconstruction through the tagged implementation
/// (the trait's third stage; every stock predictor shares
/// [`LayerState::absorb`], but the dispatch keeps a specialized
/// implementation honest).
pub fn absorb_with_tag(tag: PredTag, beta: f32, st: &mut LayerState, recon: &[f32]) {
    with_tag_impl(tag, beta, |p| p.absorb(st, recon));
}

/// The production predictor: normalized EMA with per-layer memory.
#[derive(Debug, Clone)]
pub struct EmaNormPredictor {
    /// Decay factor β in `m' = β·m + (1−β)·z`.
    pub beta: f32,
    /// Memory tensor `m`, same shape as the layer. `None` until round 2.
    pub memory: Option<Vec<f32>>,
}

impl EmaNormPredictor {
    pub fn new(beta: f32) -> Self {
        EmaNormPredictor { beta, memory: None }
    }

    /// Predict the current magnitude tensor and update the memory.
    ///
    /// `prev_abs` is `|g̃^(t-1)|`; `mu_curr`/`sigma_curr` are the scalar
    /// stats of the *current* absolute gradient (transmitted in the
    /// payload). Returns zeros on the first round (no history yet — the
    /// pipeline treats â=0 as "no prediction").
    pub fn predict(&mut self, prev_abs: Option<&[f32]>, mu_curr: f32, sigma_curr: f32) -> Vec<f32> {
        let mut out = Vec::new();
        self.predict_into(prev_abs, mu_curr, sigma_curr, &mut out);
        out
    }

    /// [`Self::predict`] into a caller-owned buffer (hoists the per-call
    /// output allocation into a reusable scratch). Delegates to the
    /// [`EmaPredictor`] trait impl, so the EMA math exists once.
    pub fn predict_into(
        &mut self,
        prev_abs: Option<&[f32]>,
        mu_curr: f32,
        sigma_curr: f32,
        out: &mut Vec<f32>,
    ) {
        out.clear();
        let Some(prev) = prev_abs else {
            return; // round 1: no prediction
        };
        let n = prev.len();
        let mem = self.memory.get_or_insert_with(Vec::new);
        assert!(mem.is_empty() || mem.len() == n, "layer size changed between rounds");
        let plan = MagPlan::of(Some(prev));
        EmaPredictor { beta: self.beta }
            .predict_into(&plan, Some(prev), mem, mu_curr, sigma_curr, n, out)
            .expect("lengths checked above");
    }

    pub fn reset(&mut self) {
        self.memory = None;
    }
}

/// Ablation variants of Table 1. All predict the current magnitude tensor
/// from history of (reconstructed) magnitude tensors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MagnitudeVariant {
    /// Lorenzo in time: â^(t) = ã^(t-1).
    Lorenzo,
    /// Moving average over a sliding window of w rounds.
    MovingAverage(usize),
    /// First-order autoregressive model with online-estimated φ.
    Ar1,
    /// EMA directly on raw magnitudes (no normalization).
    EmaNoNorm,
    /// The production predictor (Alg. 1).
    EmaNorm,
}

impl MagnitudeVariant {
    pub fn name(&self) -> String {
        match self {
            MagnitudeVariant::Lorenzo => "Lorenzo".into(),
            MagnitudeVariant::MovingAverage(w) => format!("MA (w={w})"),
            MagnitudeVariant::Ar1 => "AR(1)".into(),
            MagnitudeVariant::EmaNoNorm => "EMA (No Norm)".into(),
            MagnitudeVariant::EmaNorm => "EMA (Norm)".into(),
        }
    }
}

/// Stateful runner for any [`MagnitudeVariant`] — used by the Table-1
/// ablation bench. Feed the *true* magnitude tensors round by round;
/// `step` returns the prediction made *before* seeing the new tensor.
pub struct VariantRunner {
    variant: MagnitudeVariant,
    beta: f32,
    history: Vec<Vec<f32>>,
    ema_norm: EmaNormPredictor,
    ema_raw: Option<Vec<f32>>,
    /// Online AR(1) sufficient statistics (lag-1 cross/auto products).
    ar_num: f64,
    ar_den: f64,
    /// Reusable prediction buffer (hoisted out of the per-round loop).
    scratch: Vec<f32>,
}

impl VariantRunner {
    pub fn new(variant: MagnitudeVariant, beta: f32) -> Self {
        VariantRunner {
            variant,
            beta,
            history: Vec::new(),
            ema_norm: EmaNormPredictor::new(beta),
            ema_raw: None,
            ar_num: 0.0,
            ar_den: 0.0,
            scratch: Vec::new(),
        }
    }

    /// Predict the magnitude tensor for this round, then absorb the truth.
    pub fn step(&mut self, truth_abs: &[f32]) -> Vec<f32> {
        let n = truth_abs.len();
        let pred = match self.variant {
            MagnitudeVariant::Lorenzo => {
                self.history.last().cloned().unwrap_or_else(|| vec![0.0; n])
            }
            MagnitudeVariant::MovingAverage(w) => {
                if self.history.is_empty() {
                    vec![0.0; n]
                } else {
                    let take = self.history.len().min(w);
                    let slice = &self.history[self.history.len() - take..];
                    let mut out = vec![0.0f32; n];
                    for h in slice {
                        for i in 0..n {
                            out[i] += h[i];
                        }
                    }
                    for v in &mut out {
                        *v /= take as f32;
                    }
                    out
                }
            }
            MagnitudeVariant::Ar1 => {
                let phi = if self.ar_den > 0.0 {
                    (self.ar_num / self.ar_den).clamp(-1.0, 1.0) as f32
                } else {
                    1.0
                };
                match self.history.last() {
                    Some(prev) => prev.iter().map(|&x| phi * x).collect(),
                    None => vec![0.0; n],
                }
            }
            MagnitudeVariant::EmaNoNorm => match &self.ema_raw {
                Some(m) => m.clone(),
                None => vec![0.0; n],
            },
            MagnitudeVariant::EmaNorm => {
                let (mu, sigma) = stats::mean_std(truth_abs);
                let prev = self.history.last().map(|v| v.as_slice());
                self.ema_norm.predict_into(prev, mu, sigma, &mut self.scratch);
                if self.scratch.is_empty() {
                    vec![0.0; n]
                } else {
                    // Hand the buffer out instead of copying it; the next
                    // predict_into refills a fresh one.
                    std::mem::take(&mut self.scratch)
                }
            }
        };
        // Absorb truth into state.
        if let Some(prev) = self.history.last() {
            for i in 0..n {
                self.ar_num += (prev[i] as f64) * (truth_abs[i] as f64);
                self.ar_den += (prev[i] as f64) * (prev[i] as f64);
            }
        }
        match &mut self.ema_raw {
            Some(m) => ema_update(m, truth_abs, self.beta),
            None => self.ema_raw = Some(truth_abs.to_vec()),
        }
        self.history.push(truth_abs.to_vec());
        if self.history.len() > 8 {
            self.history.remove(0);
        }
        pred
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn first_round_no_prediction() {
        let mut p = EmaNormPredictor::new(0.9);
        assert!(p.predict(None, 1.0, 0.5).is_empty());
    }

    #[test]
    fn prediction_is_nonnegative() {
        let mut p = EmaNormPredictor::new(0.5);
        let prev = vec![0.1f32, 0.9, 0.0, 0.4];
        let out = p.predict(Some(&prev), 0.01, 0.5);
        assert!(out.iter().all(|&x| x >= 0.0));
        assert_eq!(out.len(), prev.len());
    }

    #[test]
    fn memory_update_matches_formula() {
        let mut p = EmaNormPredictor::new(0.8);
        let prev = vec![1.0f32, 2.0, 3.0, 4.0];
        let (mu_p, sg_p) = stats::mean_std(&prev);
        let out = p.predict(Some(&prev), 10.0, 2.0);
        for i in 0..prev.len() {
            let z = (prev[i] - mu_p) / sg_p.max(SIGMA_EPS);
            let m = 0.2 * z; // memory starts at 0
            let want = (m * 2.0 + 10.0).max(0.0);
            assert!((out[i] - want).abs() < 1e-6, "i={i} out={} want={want}", out[i]);
        }
    }

    #[test]
    fn stationary_signal_converges() {
        // For a constant normalized pattern, prediction should converge to it.
        let mut p = EmaNormPredictor::new(0.5);
        let pattern = vec![0.1f32, 0.2, 0.3, 0.8];
        let (mu, sg) = stats::mean_std(&pattern);
        let mut pred = Vec::new();
        for _ in 0..30 {
            pred = p.predict(Some(&pattern), mu, sg);
        }
        for (a, b) in pred.iter().zip(&pattern) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn constant_tensor_does_not_nan() {
        let mut p = EmaNormPredictor::new(0.9);
        let prev = vec![0.5f32; 16];
        let out = p.predict(Some(&prev), 0.5, 0.0);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    /// The Table-1 ordering: on synthetic magnitude sequences with
    /// non-stationary scale + stable normalized pattern, EMA(Norm) must
    /// beat Lorenzo and EMA(NoNorm) on MSE — the paper's qualitative
    /// claim.
    #[test]
    fn ema_norm_wins_on_nonstationary_sequences() {
        let mut rng = Rng::new(42);
        let n = 256;
        let pattern: Vec<f32> = (0..n).map(|_| rng.uniform(0.2, 1.0) as f32).collect();
        let rounds = 60;
        let mut runners = [
            VariantRunner::new(MagnitudeVariant::Lorenzo, 0.9),
            VariantRunner::new(MagnitudeVariant::EmaNoNorm, 0.9),
            VariantRunner::new(MagnitudeVariant::EmaNorm, 0.9),
        ];
        let mut errs = [0.0f64; 3];
        for t in 0..rounds {
            // Scale decays like real training, with strong round-to-round
            // jitter — the non-stationarity that per-epoch normalization
            // corrects (the EMA tracks a stationary pattern in z-space,
            // while Lorenzo/EMA-raw absorb the full scale error).
            let jitter = (0.35 * rng.gauss()).exp();
            let scale = (jitter / (1.0 + 0.2 * t as f64)) as f32;
            let truth: Vec<f32> = pattern
                .iter()
                .map(|&p| (p * scale * (1.0 + 0.1 * rng.gauss() as f32)).abs())
                .collect();
            for (k, r) in runners.iter_mut().enumerate() {
                let pred = r.step(&truth);
                if t > 3 {
                    errs[k] += stats::mse(&pred, &truth);
                }
            }
        }
        assert!(errs[2] < errs[0], "EMA(Norm) {} vs Lorenzo {}", errs[2], errs[0]);
        assert!(errs[2] < errs[1], "EMA(Norm) {} vs EMA(NoNorm) {}", errs[2], errs[1]);
    }

    #[test]
    fn selector_and_tag_names_roundtrip() {
        for sel in MagnitudeSel::ALL {
            assert_eq!(MagnitudeSel::from_name(sel.name()), Some(sel));
        }
        assert_eq!(MagnitudeSel::from_name("bogus"), None);
        assert_eq!(MagnitudeSel::default(), MagnitudeSel::Ema);
        for tag in PredTag::ALL {
            assert_eq!(PredTag::from_tag(tag.tag()).unwrap(), tag);
            assert_eq!(MagnitudeSel::from_name(tag.name()).unwrap().name(), tag.name());
        }
        assert!(PredTag::from_tag(9).is_err());
        // Every registry row names a parseable selector.
        for fam in MAG_REGISTRY {
            assert!(MagnitudeSel::from_name(fam.name).is_some(), "{}", fam.name);
        }
        // Selector state tags are distinct (fingerprint discrimination).
        let mut tags: Vec<u8> = MagnitudeSel::ALL.iter().map(|s| s.state_tag()).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), MagnitudeSel::ALL.len());
    }

    #[test]
    fn trait_impls_match_reference_predictors() {
        // EmaPredictor (the trait impl) must agree bit-for-bit with
        // EmaNormPredictor (the Alg. 1 reference) — they share
        // ema_norm_step, and this pins the delegation.
        let mut rng = Rng::new(9);
        let n = 257;
        let prev: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let (mu, sigma) = (0.4f32, 0.2f32);
        let mut reference = EmaNormPredictor::new(0.85);
        let mut r1 = Vec::new();
        let mut r2 = Vec::new();
        let mut mem = Vec::new();
        let ema = EmaPredictor { beta: 0.85 };
        for _ in 0..3 {
            reference.predict_into(Some(&prev), mu, sigma, &mut r1);
            let plan = ema.plan(Some(&prev));
            ema.predict_into(&plan, Some(&prev), &mut mem, mu, sigma, n, &mut r2).unwrap();
            assert_eq!(r1, r2);
        }
        assert_eq!(reference.memory.as_deref(), Some(mem.as_slice()));

        // Last = previous magnitudes verbatim; Zero = zeros; both leave
        // memory untouched and predict zeros on round 1.
        let plan = MagPlan::of(Some(&prev));
        let mut out = Vec::new();
        let mut untouched = vec![7.0f32; 3];
        LastPredictor
            .predict_into(&plan, Some(&prev), &mut untouched, mu, sigma, n, &mut out)
            .unwrap();
        assert_eq!(out, prev);
        ZeroPredictor
            .predict_into(&plan, Some(&prev), &mut untouched, mu, sigma, n, &mut out)
            .unwrap();
        assert!(out.iter().all(|&x| x == 0.0) && out.len() == n);
        assert_eq!(untouched, vec![7.0f32; 3]);
        for tag in PredTag::ALL {
            predict_with_tag(tag, 0.9, None, &mut untouched, mu, sigma, 5, &mut out).unwrap();
            assert_eq!(out, vec![0.0; 5], "{tag:?} round 1");
        }
        // absorb dispatches through the trait (shared LayerState::absorb
        // for every stock predictor).
        let mut st = LayerState::default();
        absorb_with_tag(PredTag::Last, 0.9, &mut st, &[1.0, -2.0]);
        assert_eq!(st.prev_abs.as_deref(), Some(&[1.0, 2.0][..]));
        // Shape mismatches surface as Err, not UB.
        assert!(LastPredictor
            .predict_into(&plan, Some(&prev), &mut untouched, mu, sigma, n + 1, &mut out)
            .is_err());
        assert!(EmaPredictor { beta: 0.9 }
            .predict_into(&plan, Some(&prev), &mut untouched, mu, sigma, n + 1, &mut out)
            .is_err());
    }

    #[test]
    fn ema_update_matches_inline_formula() {
        let mut m = vec![1.0f32, -2.0, 0.5];
        let x = vec![0.0f32, 4.0, 0.5];
        ema_update(&mut m, &x, 0.75);
        for (got, (m0, x0)) in m.iter().zip([(1.0f32, 0.0f32), (-2.0, 4.0), (0.5, 0.5)]) {
            assert_eq!(*got, 0.75 * m0 + 0.25 * x0);
        }
    }
}
