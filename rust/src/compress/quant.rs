//! Stage 2: the error-bounded quantizer.
//!
//! SZ-style linear quantization: residual `e` maps to integer code
//! `round(e / 2Δ)`; reconstruction is `code · 2Δ`, so the pointwise error
//! is at most Δ. Values whose reconstruction would violate the bound in
//! f32 arithmetic (or whose code exceeds the alphabet radius) are
//! **escaped** to exact f32 — the bound therefore holds unconditionally,
//! which the property tests assert for arbitrary inputs including
//! NaN/Inf (non-finite values are always escaped verbatim).

use super::kernels;
use super::kernels::CHUNK;

/// Error-bound mode, mirroring SZ's ABS / REL conventions (paper Alg. 3
/// `ErrMode`, Δ).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ErrorBound {
    /// Absolute bound: |x̃ − x| ≤ Δ.
    Abs(f64),
    /// Range-relative bound: Δ = eb · (max − min) of the layer.
    Rel(f64),
}

impl ErrorBound {
    /// Resolve to an absolute Δ for data with the given finite range.
    pub fn resolve(&self, lo: f32, hi: f32) -> f64 {
        match *self {
            ErrorBound::Abs(d) => d,
            ErrorBound::Rel(eb) => {
                let range = (hi - lo) as f64;
                if range > 0.0 {
                    eb * range
                } else {
                    // Degenerate (constant) data: any positive delta works.
                    eb * lo.abs().max(1e-30) as f64
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ErrorBound::Abs(_) => "ABS",
            ErrorBound::Rel(_) => "REL",
        }
    }

    /// Canonical 32-bit encoding of the bound for the mirror fingerprint
    /// and the FGS3 spill record. Valid bounds are positive, so the f32
    /// sign bit is free to carry the mode: clear = Rel, set = Abs. The
    /// all-zero word never occurs (a zero bound is rejected at parse
    /// time) and serves as the "unset" sentinel in `LayerState`.
    pub fn state_bits(&self) -> u32 {
        match *self {
            ErrorBound::Rel(v) => (v as f32).to_bits(),
            ErrorBound::Abs(v) => (v as f32).to_bits() | 0x8000_0000,
        }
    }
}

/// Codes with |code| above this are escaped. Keeps the Huffman alphabet
/// bounded and i32 arithmetic overflow-free.
pub const CODE_RADIUS: i32 = 1 << 24;

/// Output of quantizing one layer.
#[derive(Debug, Clone, Default)]
pub struct Quantized {
    /// Per-element codes; escaped elements carry code = i32::MIN marker.
    pub codes: Vec<i32>,
    /// Exact values for escaped positions, in element order.
    pub escapes: Vec<f32>,
}

/// Marker stored in `codes` for escaped elements.
pub const ESCAPE_CODE: i32 = i32::MIN;

/// Radius of the flat-array fast path used when histogramming codes or
/// building symbol lookup tables: gradient residual codes concentrate
/// near 0 (§Perf), so symbols in `[-FAST_RADIUS, FAST_RADIUS]` are
/// counted with array indexing and only the rare outliers go through a
/// `HashMap`.
pub const FAST_RADIUS: i32 = 4096;

/// Per-symbol frequency histogram of a quantization-code stream, sorted
/// by symbol. This is the shared front door of the entropy stage: the
/// Huffman table build, the rANS frequency normalization and the
/// autotuner's coder chooser all consume it.
pub fn code_histogram(codes: &[i32]) -> Vec<(i32, u64)> {
    if codes.is_empty() {
        return Vec::new();
    }
    let flat_len = (2 * FAST_RADIUS + 1) as usize;
    let mut flat = vec![0u64; flat_len];
    let mut overflow: std::collections::HashMap<i32, u64> = std::collections::HashMap::new();
    for &c in codes {
        if (-FAST_RADIUS..=FAST_RADIUS).contains(&c) {
            flat[(c + FAST_RADIUS) as usize] += 1;
        } else {
            *overflow.entry(c).or_insert(0) += 1;
        }
    }
    let mut freqs: Vec<(i32, u64)> = flat
        .iter()
        .enumerate()
        .filter(|(_, &f)| f > 0)
        .map(|(i, &f)| (i as i32 - FAST_RADIUS, f))
        .collect();
    freqs.extend(overflow);
    freqs.sort_unstable_by_key(|&(s, _)| s);
    freqs
}

/// One quantizer step — residual, round-half-up code, reconstruction and
/// the in-bound test. `ok = false` means "escape verbatim". Shared by the
/// scalar twin, the chunked fast kernel and its tail loop, so the twins
/// are bit-identical by construction (DESIGN.md §12).
#[inline]
fn quant_step(
    x: f32,
    p: f32,
    two_delta: f32,
    inv_two_delta: f32,
    delta_f: f32,
) -> (i32, f32, bool) {
    if !x.is_finite() || two_delta <= 0.0 {
        return (ESCAPE_CODE, x, false);
    }
    let e = x - p;
    // round-half-up to match the Pallas kernel (see compress::fused).
    let code_f = (e * inv_two_delta + 0.5).floor();
    if code_f.abs() > CODE_RADIUS as f32 {
        return (ESCAPE_CODE, x, false);
    }
    let code = code_f as i32;
    let r = p + code as f32 * two_delta;
    // Guard against f32 rounding breaking the bound.
    if (r - x).abs() > delta_f || !r.is_finite() {
        (ESCAPE_CODE, x, false)
    } else {
        (code, r, true)
    }
}

/// Quantize residuals `e = data − pred` under absolute bound `delta`,
/// producing codes + escapes and writing reconstructions to `recon`
/// (`recon[i] = pred[i] + 2Δ·code` or the exact value when escaped).
pub fn quantize(
    data: &[f32],
    pred: &[f32],
    delta: f64,
    out: &mut Quantized,
    recon: &mut Vec<f32>,
) {
    assert_eq!(data.len(), pred.len());
    let two_delta = (2.0 * delta) as f32;
    let inv_two_delta = if two_delta > 0.0 { 1.0 / two_delta } else { 0.0 };
    out.codes.clear();
    out.codes.reserve(data.len());
    out.escapes.clear();
    recon.clear();
    recon.reserve(data.len());
    let delta_f = delta as f32;
    // Degenerate bins (2Δ ≤ 0 ⇒ everything escapes) stay on the scalar
    // twin; the chunked kernel covers the real quantization path.
    if two_delta > 0.0 && !kernels::scalar_kernels() {
        quantize_fast(data, pred, two_delta, inv_two_delta, delta_f, out, recon);
    } else {
        quantize_scalar(data, pred, two_delta, inv_two_delta, delta_f, out, recon);
    }
}

fn quantize_scalar(
    data: &[f32],
    pred: &[f32],
    two_delta: f32,
    inv_two_delta: f32,
    delta_f: f32,
    out: &mut Quantized,
    recon: &mut Vec<f32>,
) {
    for (&x, &p) in data.iter().zip(pred.iter()) {
        let (code, r, ok) = quant_step(x, p, two_delta, inv_two_delta, delta_f);
        if ok {
            out.codes.push(code);
            recon.push(r);
        } else {
            out.codes.push(ESCAPE_CODE);
            out.escapes.push(x);
            recon.push(x);
        }
    }
}

/// Fast twin: [`CHUNK`]-wide array-ref chunks (compile-time trip counts
/// for the autovectorizer) with a per-chunk escape mask — all-in-bound
/// chunks bulk-extend the outputs, chunks containing an escape fall back
/// to a per-lane loop.
fn quantize_fast(
    data: &[f32],
    pred: &[f32],
    two_delta: f32,
    inv_two_delta: f32,
    delta_f: f32,
    out: &mut Quantized,
    recon: &mut Vec<f32>,
) {
    let n = data.len();
    debug_assert_eq!(pred.len(), n);
    let chunks = n / CHUNK;
    for c in 0..chunks {
        let base = c * CHUNK;
        // SAFETY: `base + CHUNK = (c + 1) * CHUNK ≤ chunks * CHUNK ≤ n`,
        // and `data.len() == pred.len() == n` (asserted by `quantize`,
        // debug-asserted above), so both `CHUNK`-wide array refs are
        // fully in bounds.
        let (d, p) = unsafe {
            (
                &*(data.as_ptr().add(base) as *const [f32; CHUNK]),
                &*(pred.as_ptr().add(base) as *const [f32; CHUNK]),
            )
        };
        let mut code = [0i32; CHUNK];
        let mut rec = [0f32; CHUNK];
        let mut ok = [false; CHUNK];
        let mut all_ok = true;
        for l in 0..CHUNK {
            let (ci, r, o) = quant_step(d[l], p[l], two_delta, inv_two_delta, delta_f);
            code[l] = ci;
            rec[l] = r;
            ok[l] = o;
            all_ok &= o;
        }
        if all_ok {
            out.codes.extend_from_slice(&code);
            recon.extend_from_slice(&rec);
        } else {
            for l in 0..CHUNK {
                if ok[l] {
                    out.codes.push(code[l]);
                    recon.push(rec[l]);
                } else {
                    out.codes.push(ESCAPE_CODE);
                    out.escapes.push(d[l]);
                    recon.push(d[l]);
                }
            }
        }
    }
    // Scalar tail over the final `n % CHUNK` elements — the shared
    // `quant_step` makes the seam invisible in the output.
    for i in chunks * CHUNK..n {
        let (code, r, ok) = quant_step(data[i], pred[i], two_delta, inv_two_delta, delta_f);
        if ok {
            out.codes.push(code);
            recon.push(r);
        } else {
            out.codes.push(ESCAPE_CODE);
            out.escapes.push(data[i]);
            recon.push(data[i]);
        }
    }
}

/// Number of escaped elements in a code stream — the consistency check
/// shared by [`dequantize_checked`] and the compressed-domain
/// aggregator ([`crate::compress::agg`]), which must validate an
/// untrusted escape stream *without* reconstructing the layer.
pub fn count_escapes(codes: &[i32]) -> usize {
    codes.iter().filter(|&&c| c == ESCAPE_CODE).count()
}

/// Reconstruct from codes + escapes given the same predictions and Δ.
/// Trusted-caller form (panics on an inconsistent escape stream);
/// untrusted payloads go through [`dequantize_checked`].
pub fn dequantize(q: &Quantized, pred: &[f32], delta: f64, recon: &mut Vec<f32>) {
    dequantize_checked(q, pred, delta, recon).expect("dequantize: inconsistent stream");
}

/// [`dequantize`] for untrusted streams: a corrupt payload whose escape
/// stream is shorter or longer than its escape codes claim surfaces as
/// `Err`, not a panic.
pub fn dequantize_checked(
    q: &Quantized,
    pred: &[f32],
    delta: f64,
    recon: &mut Vec<f32>,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        q.codes.len() == pred.len(),
        "dequantize: {} codes for {} predictions",
        q.codes.len(),
        pred.len()
    );
    let two_delta = (2.0 * delta) as f32;
    recon.clear();
    recon.reserve(pred.len());
    if kernels::scalar_kernels() {
        let mut esc = q.escapes.iter();
        for (i, &code) in q.codes.iter().enumerate() {
            if code == ESCAPE_CODE {
                let v = *esc
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("escape stream exhausted"))?;
                recon.push(v);
            } else {
                recon.push(pred[i] + code as f32 * two_delta);
            }
        }
        anyhow::ensure!(esc.next().is_none(), "unconsumed escapes");
    } else {
        dequantize_fast(&q.codes, &q.escapes, pred, two_delta, recon)?;
    }
    Ok(())
}

/// Fast twin of the dequantizer body: escape-free chunks (the common
/// case) run a branchless reconstruct loop; the rest fall back per lane.
fn dequantize_fast(
    codes: &[i32],
    escapes: &[f32],
    pred: &[f32],
    two_delta: f32,
    recon: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let n = codes.len();
    debug_assert_eq!(pred.len(), n);
    let chunks = n / CHUNK;
    let mut esc = 0usize;
    for c in 0..chunks {
        let base = c * CHUNK;
        // SAFETY: `base + CHUNK ≤ chunks * CHUNK ≤ n` and
        // `codes.len() == pred.len() == n` (ensured by the caller,
        // debug-asserted above), so both array refs are in bounds.
        let (co, p) = unsafe {
            (
                &*(codes.as_ptr().add(base) as *const [i32; CHUNK]),
                &*(pred.as_ptr().add(base) as *const [f32; CHUNK]),
            )
        };
        let mut any_escape = false;
        for l in 0..CHUNK {
            any_escape |= co[l] == ESCAPE_CODE;
        }
        if !any_escape {
            for l in 0..CHUNK {
                recon.push(p[l] + co[l] as f32 * two_delta);
            }
        } else {
            for l in 0..CHUNK {
                if co[l] == ESCAPE_CODE {
                    let v = *escapes
                        .get(esc)
                        .ok_or_else(|| anyhow::anyhow!("escape stream exhausted"))?;
                    esc += 1;
                    recon.push(v);
                } else {
                    recon.push(p[l] + co[l] as f32 * two_delta);
                }
            }
        }
    }
    for i in chunks * CHUNK..n {
        if codes[i] == ESCAPE_CODE {
            let v = *escapes
                .get(esc)
                .ok_or_else(|| anyhow::anyhow!("escape stream exhausted"))?;
            esc += 1;
            recon.push(v);
        } else {
            recon.push(pred[i] + codes[i] as f32 * two_delta);
        }
    }
    anyhow::ensure!(esc == escapes.len(), "unconsumed escapes");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn rel_bound_resolution() {
        let eb = ErrorBound::Rel(0.01);
        assert!((eb.resolve(-1.0, 1.0) - 0.02).abs() < 1e-12);
        assert!(eb.resolve(3.0, 3.0) > 0.0); // degenerate range
        assert_eq!(ErrorBound::Abs(0.5).resolve(0.0, 100.0), 0.5);
    }

    #[test]
    fn quantize_respects_bound() {
        let data = vec![0.5f32, -0.3, 1.7, 0.0, -2.2];
        let pred = vec![0.4f32, -0.1, 1.0, 0.1, -2.0];
        let delta = 0.05;
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        quantize(&data, &pred, delta, &mut q, &mut recon);
        for (r, x) in recon.iter().zip(&data) {
            assert!((r - x).abs() <= delta as f32 + 1e-9, "r={r} x={x}");
        }
    }

    #[test]
    fn dequantize_matches_encoder_recon() {
        let data = vec![0.5f32, -0.3, 1.7, f32::NAN, -2.2];
        let pred = vec![0.0f32; 5];
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        quantize(&data, &pred, 0.01, &mut q, &mut recon);
        let mut recon2 = Vec::new();
        dequantize(&q, &pred, 0.01, &mut recon2);
        for (a, b) in recon.iter().zip(&recon2) {
            assert!(a == b || (a.is_nan() && b.is_nan()));
        }
    }

    #[test]
    fn dequantize_checked_rejects_inconsistent_escape_streams() {
        let q = Quantized { codes: vec![0, ESCAPE_CODE, 1], escapes: vec![] };
        let mut recon = Vec::new();
        assert!(dequantize_checked(&q, &[0.0; 3], 0.1, &mut recon).is_err());
        let q = Quantized { codes: vec![0, 1], escapes: vec![9.0] };
        assert!(dequantize_checked(&q, &[0.0; 2], 0.1, &mut recon).is_err());
        assert!(dequantize_checked(&q, &[0.0; 3], 0.1, &mut recon).is_err(), "len mismatch");
        let q = Quantized { codes: vec![ESCAPE_CODE, 2], escapes: vec![7.5] };
        dequantize_checked(&q, &[0.0, 1.0], 0.05, &mut recon).unwrap();
        assert_eq!(recon, vec![7.5, 1.0 + 2.0 * 0.1]);
    }

    #[test]
    fn nan_and_inf_escape_verbatim() {
        let data = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0];
        let pred = vec![0.0f32; 4];
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        quantize(&data, &pred, 0.1, &mut q, &mut recon);
        assert!(recon[0].is_nan());
        assert_eq!(recon[1], f32::INFINITY);
        assert_eq!(recon[2], f32::NEG_INFINITY);
        assert_eq!(q.escapes.len(), 3);
    }

    #[test]
    fn huge_residual_escapes() {
        let data = vec![1e30f32];
        let pred = vec![0.0f32];
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        quantize(&data, &pred, 1e-6, &mut q, &mut recon);
        assert_eq!(q.codes[0], ESCAPE_CODE);
        assert_eq!(recon[0], 1e30);
    }

    #[test]
    fn count_escapes_matches_stream() {
        assert_eq!(count_escapes(&[]), 0);
        assert_eq!(count_escapes(&[1, ESCAPE_CODE, -2, ESCAPE_CODE, 0]), 2);
        let data = vec![f32::NAN, 1.0, f32::INFINITY];
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        quantize(&data, &[0.0; 3], 0.1, &mut q, &mut recon);
        assert_eq!(count_escapes(&q.codes), q.escapes.len());
    }

    #[test]
    fn code_histogram_counts_and_sorts() {
        assert!(code_histogram(&[]).is_empty());
        let h = code_histogram(&[3, -1, 3, ESCAPE_CODE, 3, -1, 1 << 20]);
        assert_eq!(h, vec![(ESCAPE_CODE, 1), (-1, 2), (3, 3), (1 << 20, 1)]);
        let total: u64 = h.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 7);
    }

    #[test]
    fn scalar_and_fast_twins_agree_bitwise() {
        prop::check("quant scalar==fast", 120, |rng| {
            let n = prop::arb_len(rng, 2000);
            let data = prop::arb_gradient(rng, n);
            let pred = prop::arb_gradient(rng, n);
            let delta = prop::arb_error_bound(rng);
            let mut qf = Quantized::default();
            let mut rf = Vec::new();
            quantize(&data, &pred, delta, &mut qf, &mut rf);
            let (mut qs, mut rs) = (Quantized::default(), Vec::new());
            kernels::with_scalar_kernels(|| quantize(&data, &pred, delta, &mut qs, &mut rs));
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            if qf.codes != qs.codes {
                return Err("codes diverge".into());
            }
            if bits(&qf.escapes) != bits(&qs.escapes) {
                return Err("escapes diverge".into());
            }
            if bits(&rf) != bits(&rs) {
                return Err("encode recon diverges".into());
            }
            let mut df = Vec::new();
            dequantize_checked(&qf, &pred, delta, &mut df).map_err(|e| e.to_string())?;
            let mut ds = Vec::new();
            kernels::with_scalar_kernels(|| {
                dequantize_checked(&qf, &pred, delta, &mut ds).map_err(|e| e.to_string())
            })?;
            if bits(&df) != bits(&ds) {
                return Err("decode recon diverges".into());
            }
            Ok(())
        });
    }

    #[test]
    fn state_bits_separates_modes_and_magnitudes() {
        // Same magnitude, different mode: distinct words.
        assert_ne!(ErrorBound::Rel(1e-2).state_bits(), ErrorBound::Abs(1e-2).state_bits());
        // Same mode, different magnitude: distinct words.
        assert_ne!(ErrorBound::Rel(1e-2).state_bits(), ErrorBound::Rel(2e-2).state_bits());
        // Valid (positive) bounds never collide with the 0 "unset" sentinel.
        for eb in [1e-30, 1e-3, 0.5, 100.0] {
            assert_ne!(ErrorBound::Rel(eb).state_bits(), 0);
            assert_ne!(ErrorBound::Abs(eb).state_bits(), 0);
        }
    }

    #[test]
    fn property_bound_never_violated() {
        prop::check("quantize bound", 200, |rng| {
            let n = prop::arb_len(rng, 2000);
            let data = prop::arb_gradient(rng, n);
            let pred = prop::arb_gradient(rng, n);
            let delta = prop::arb_error_bound(rng);
            let mut q = Quantized::default();
            let mut recon = Vec::new();
            quantize(&data, &pred, delta, &mut q, &mut recon);
            for i in 0..n {
                let err = (recon[i] - data[i]).abs();
                if data[i].is_finite() && err > delta as f32 * 1.0001 {
                    return Err(format!("i={i} err={err} delta={delta}"));
                }
            }
            Ok(())
        });
    }
}
