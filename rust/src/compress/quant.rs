//! Stage 2: the error-bounded quantizer.
//!
//! SZ-style linear quantization: residual `e` maps to integer code
//! `round(e / 2Δ)`; reconstruction is `code · 2Δ`, so the pointwise error
//! is at most Δ. Values whose reconstruction would violate the bound in
//! f32 arithmetic (or whose code exceeds the alphabet radius) are
//! **escaped** to exact f32 — the bound therefore holds unconditionally,
//! which the property tests assert for arbitrary inputs including
//! NaN/Inf (non-finite values are always escaped verbatim).

/// Error-bound mode, mirroring SZ's ABS / REL conventions (paper Alg. 3
/// `ErrMode`, Δ).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ErrorBound {
    /// Absolute bound: |x̃ − x| ≤ Δ.
    Abs(f64),
    /// Range-relative bound: Δ = eb · (max − min) of the layer.
    Rel(f64),
}

impl ErrorBound {
    /// Resolve to an absolute Δ for data with the given finite range.
    pub fn resolve(&self, lo: f32, hi: f32) -> f64 {
        match *self {
            ErrorBound::Abs(d) => d,
            ErrorBound::Rel(eb) => {
                let range = (hi - lo) as f64;
                if range > 0.0 {
                    eb * range
                } else {
                    // Degenerate (constant) data: any positive delta works.
                    eb * lo.abs().max(1e-30) as f64
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ErrorBound::Abs(_) => "ABS",
            ErrorBound::Rel(_) => "REL",
        }
    }
}

/// Codes with |code| above this are escaped. Keeps the Huffman alphabet
/// bounded and i32 arithmetic overflow-free.
pub const CODE_RADIUS: i32 = 1 << 24;

/// Output of quantizing one layer.
#[derive(Debug, Clone, Default)]
pub struct Quantized {
    /// Per-element codes; escaped elements carry code = i32::MIN marker.
    pub codes: Vec<i32>,
    /// Exact values for escaped positions, in element order.
    pub escapes: Vec<f32>,
}

/// Marker stored in `codes` for escaped elements.
pub const ESCAPE_CODE: i32 = i32::MIN;

/// Radius of the flat-array fast path used when histogramming codes or
/// building symbol lookup tables: gradient residual codes concentrate
/// near 0 (§Perf), so symbols in `[-FAST_RADIUS, FAST_RADIUS]` are
/// counted with array indexing and only the rare outliers go through a
/// `HashMap`.
pub const FAST_RADIUS: i32 = 4096;

/// Per-symbol frequency histogram of a quantization-code stream, sorted
/// by symbol. This is the shared front door of the entropy stage: the
/// Huffman table build, the rANS frequency normalization and the
/// autotuner's coder chooser all consume it.
pub fn code_histogram(codes: &[i32]) -> Vec<(i32, u64)> {
    if codes.is_empty() {
        return Vec::new();
    }
    let flat_len = (2 * FAST_RADIUS + 1) as usize;
    let mut flat = vec![0u64; flat_len];
    let mut overflow: std::collections::HashMap<i32, u64> = std::collections::HashMap::new();
    for &c in codes {
        if (-FAST_RADIUS..=FAST_RADIUS).contains(&c) {
            flat[(c + FAST_RADIUS) as usize] += 1;
        } else {
            *overflow.entry(c).or_insert(0) += 1;
        }
    }
    let mut freqs: Vec<(i32, u64)> = flat
        .iter()
        .enumerate()
        .filter(|(_, &f)| f > 0)
        .map(|(i, &f)| (i as i32 - FAST_RADIUS, f))
        .collect();
    freqs.extend(overflow);
    freqs.sort_unstable_by_key(|&(s, _)| s);
    freqs
}

/// Quantize residuals `e = data − pred` under absolute bound `delta`,
/// producing codes + escapes and writing reconstructions to `recon`
/// (`recon[i] = pred[i] + 2Δ·code` or the exact value when escaped).
pub fn quantize(
    data: &[f32],
    pred: &[f32],
    delta: f64,
    out: &mut Quantized,
    recon: &mut Vec<f32>,
) {
    assert_eq!(data.len(), pred.len());
    let two_delta = (2.0 * delta) as f32;
    let inv_two_delta = if two_delta > 0.0 { 1.0 / two_delta } else { 0.0 };
    out.codes.clear();
    out.codes.reserve(data.len());
    out.escapes.clear();
    recon.clear();
    recon.reserve(data.len());
    let delta_f = delta as f32;
    for i in 0..data.len() {
        let x = data[i];
        let p = pred[i];
        if !x.is_finite() || two_delta <= 0.0 {
            out.codes.push(ESCAPE_CODE);
            out.escapes.push(x);
            recon.push(x);
            continue;
        }
        let e = x - p;
        // round-half-up to match the Pallas kernel (see compress::fused).
        let code_f = (e * inv_two_delta + 0.5).floor();
        if code_f.abs() > CODE_RADIUS as f32 {
            out.codes.push(ESCAPE_CODE);
            out.escapes.push(x);
            recon.push(x);
            continue;
        }
        let code = code_f as i32;
        let r = p + code as f32 * two_delta;
        // Guard against f32 rounding breaking the bound.
        if (r - x).abs() > delta_f || !r.is_finite() {
            out.codes.push(ESCAPE_CODE);
            out.escapes.push(x);
            recon.push(x);
        } else {
            out.codes.push(code);
            recon.push(r);
        }
    }
}

/// Number of escaped elements in a code stream — the consistency check
/// shared by [`dequantize_checked`] and the compressed-domain
/// aggregator ([`crate::compress::agg`]), which must validate an
/// untrusted escape stream *without* reconstructing the layer.
pub fn count_escapes(codes: &[i32]) -> usize {
    codes.iter().filter(|&&c| c == ESCAPE_CODE).count()
}

/// Reconstruct from codes + escapes given the same predictions and Δ.
/// Trusted-caller form (panics on an inconsistent escape stream);
/// untrusted payloads go through [`dequantize_checked`].
pub fn dequantize(q: &Quantized, pred: &[f32], delta: f64, recon: &mut Vec<f32>) {
    dequantize_checked(q, pred, delta, recon).expect("dequantize: inconsistent stream");
}

/// [`dequantize`] for untrusted streams: a corrupt payload whose escape
/// stream is shorter or longer than its escape codes claim surfaces as
/// `Err`, not a panic.
pub fn dequantize_checked(
    q: &Quantized,
    pred: &[f32],
    delta: f64,
    recon: &mut Vec<f32>,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        q.codes.len() == pred.len(),
        "dequantize: {} codes for {} predictions",
        q.codes.len(),
        pred.len()
    );
    let two_delta = (2.0 * delta) as f32;
    recon.clear();
    recon.reserve(pred.len());
    let mut esc = q.escapes.iter();
    for (i, &code) in q.codes.iter().enumerate() {
        if code == ESCAPE_CODE {
            recon.push(*esc.next().ok_or_else(|| anyhow::anyhow!("escape stream exhausted"))?);
        } else {
            recon.push(pred[i] + code as f32 * two_delta);
        }
    }
    anyhow::ensure!(esc.next().is_none(), "unconsumed escapes");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn rel_bound_resolution() {
        let eb = ErrorBound::Rel(0.01);
        assert!((eb.resolve(-1.0, 1.0) - 0.02).abs() < 1e-12);
        assert!(eb.resolve(3.0, 3.0) > 0.0); // degenerate range
        assert_eq!(ErrorBound::Abs(0.5).resolve(0.0, 100.0), 0.5);
    }

    #[test]
    fn quantize_respects_bound() {
        let data = vec![0.5f32, -0.3, 1.7, 0.0, -2.2];
        let pred = vec![0.4f32, -0.1, 1.0, 0.1, -2.0];
        let delta = 0.05;
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        quantize(&data, &pred, delta, &mut q, &mut recon);
        for (r, x) in recon.iter().zip(&data) {
            assert!((r - x).abs() <= delta as f32 + 1e-9, "r={r} x={x}");
        }
    }

    #[test]
    fn dequantize_matches_encoder_recon() {
        let data = vec![0.5f32, -0.3, 1.7, f32::NAN, -2.2];
        let pred = vec![0.0f32; 5];
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        quantize(&data, &pred, 0.01, &mut q, &mut recon);
        let mut recon2 = Vec::new();
        dequantize(&q, &pred, 0.01, &mut recon2);
        for (a, b) in recon.iter().zip(&recon2) {
            assert!(a == b || (a.is_nan() && b.is_nan()));
        }
    }

    #[test]
    fn dequantize_checked_rejects_inconsistent_escape_streams() {
        let q = Quantized { codes: vec![0, ESCAPE_CODE, 1], escapes: vec![] };
        let mut recon = Vec::new();
        assert!(dequantize_checked(&q, &[0.0; 3], 0.1, &mut recon).is_err());
        let q = Quantized { codes: vec![0, 1], escapes: vec![9.0] };
        assert!(dequantize_checked(&q, &[0.0; 2], 0.1, &mut recon).is_err());
        assert!(dequantize_checked(&q, &[0.0; 3], 0.1, &mut recon).is_err(), "len mismatch");
        let q = Quantized { codes: vec![ESCAPE_CODE, 2], escapes: vec![7.5] };
        dequantize_checked(&q, &[0.0, 1.0], 0.05, &mut recon).unwrap();
        assert_eq!(recon, vec![7.5, 1.0 + 2.0 * 0.1]);
    }

    #[test]
    fn nan_and_inf_escape_verbatim() {
        let data = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0];
        let pred = vec![0.0f32; 4];
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        quantize(&data, &pred, 0.1, &mut q, &mut recon);
        assert!(recon[0].is_nan());
        assert_eq!(recon[1], f32::INFINITY);
        assert_eq!(recon[2], f32::NEG_INFINITY);
        assert_eq!(q.escapes.len(), 3);
    }

    #[test]
    fn huge_residual_escapes() {
        let data = vec![1e30f32];
        let pred = vec![0.0f32];
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        quantize(&data, &pred, 1e-6, &mut q, &mut recon);
        assert_eq!(q.codes[0], ESCAPE_CODE);
        assert_eq!(recon[0], 1e30);
    }

    #[test]
    fn count_escapes_matches_stream() {
        assert_eq!(count_escapes(&[]), 0);
        assert_eq!(count_escapes(&[1, ESCAPE_CODE, -2, ESCAPE_CODE, 0]), 2);
        let data = vec![f32::NAN, 1.0, f32::INFINITY];
        let mut q = Quantized::default();
        let mut recon = Vec::new();
        quantize(&data, &[0.0; 3], 0.1, &mut q, &mut recon);
        assert_eq!(count_escapes(&q.codes), q.escapes.len());
    }

    #[test]
    fn code_histogram_counts_and_sorts() {
        assert!(code_histogram(&[]).is_empty());
        let h = code_histogram(&[3, -1, 3, ESCAPE_CODE, 3, -1, 1 << 20]);
        assert_eq!(
            h,
            vec![(ESCAPE_CODE, 1), (-1, 2), (3, 3), (1 << 20, 1)]
        );
        let total: u64 = h.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 7);
    }

    #[test]
    fn property_bound_never_violated() {
        prop::check("quantize bound", 200, |rng| {
            let n = prop::arb_len(rng, 2000);
            let data = prop::arb_gradient(rng, n);
            let pred = prop::arb_gradient(rng, n);
            let delta = prop::arb_error_bound(rng);
            let mut q = Quantized::default();
            let mut recon = Vec::new();
            quantize(&data, &pred, delta, &mut q, &mut recon);
            for i in 0..n {
                let err = (recon[i] - data[i]).abs();
                if data[i].is_finite() && err > delta as f32 * 1.0001 {
                    return Err(format!("i={i} err={err} delta={delta}"));
                }
            }
            Ok(())
        });
    }
}
