//! Stage 3: canonical Huffman entropy coding over quantization codes.
//!
//! The alphabet is the set of distinct i32 codes observed in the layer
//! (bounded by [`crate::compress::quant::CODE_RADIUS`] plus the escape
//! marker). The table is serialized as `(symbol, code_length)` pairs and
//! rebuilt canonically on the decode side, so encoder and decoder agree
//! without transmitting the codes themselves.
//!
//! Falls back to a raw 32-bit store when Huffman would not help (tiny
//! inputs, pathological depth) — the blob records which mode was used.

use crate::compress::kernels;
use crate::compress::quant::{code_histogram, FAST_RADIUS};
use crate::util::bitio::BitWriter;
use std::collections::HashMap;

/// Maximum canonical code length we allow. Depths beyond this trigger the
/// raw fallback (never observed for gradient residuals; pure safety).
const MAX_LEN: u8 = 56;

/// Encoded entropy stream.
#[derive(Debug, Clone, PartialEq)]
pub enum Encoded {
    Huffman {
        /// (symbol, canonical length) table, sorted by (length, symbol).
        table: Vec<(i32, u8)>,
        /// Number of encoded symbols.
        count: u32,
        /// MSB-first bitstream.
        bits: Vec<u8>,
    },
    /// Raw little-endian i32 store.
    Raw(Vec<i32>),
}

impl Encoded {
    /// Serialized payload size in bytes (table + stream), as written by
    /// `write_to`.
    pub fn byte_size(&self) -> usize {
        match self {
            Encoded::Huffman { table, bits, .. } => 1 + 4 + 4 + table.len() * 5 + 4 + bits.len(),
            Encoded::Raw(v) => 1 + 4 + v.len() * 4,
        }
    }

    /// Append the serialized form to `out`.
    pub fn write_to(&self, out: &mut Vec<u8>) {
        match self {
            Encoded::Huffman { table, count, bits } => {
                out.push(1u8);
                out.extend_from_slice(&(table.len() as u32).to_le_bytes());
                out.extend_from_slice(&count.to_le_bytes());
                for &(sym, len) in table {
                    out.extend_from_slice(&sym.to_le_bytes());
                    out.push(len);
                }
                out.extend_from_slice(&(bits.len() as u32).to_le_bytes());
                out.extend_from_slice(bits);
            }
            Encoded::Raw(v) => {
                out.push(0u8);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for &x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    /// Symbol count a serialized stream declares, without decoding it —
    /// the layout twin of `write_to` (raw: count at offset 1; huffman:
    /// count at offset 5, after the table length). Untrusted-stream
    /// guards bound this against the expected element count before any
    /// decode work.
    pub(crate) fn declared_count(buf: &[u8]) -> anyhow::Result<u32> {
        let at = match buf.first().copied() {
            Some(0) => 1,
            Some(1) => 5,
            _ => anyhow::bail!("not a huffman/raw entropy stream"),
        };
        buf.get(at..at + 4)
            .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
            .ok_or_else(|| anyhow::anyhow!("truncated entropy stream header"))
    }

    /// Parse a serialized stream, returning (encoded, bytes_consumed).
    pub fn read_from(buf: &[u8]) -> anyhow::Result<(Encoded, usize)> {
        use anyhow::bail;
        if buf.is_empty() {
            bail!("empty entropy stream");
        }
        let mode = buf[0];
        let mut pos = 1usize;
        let rd_u32 = |buf: &[u8], pos: &mut usize| -> anyhow::Result<u32> {
            if *pos + 4 > buf.len() {
                anyhow::bail!("truncated entropy stream");
            }
            let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            Ok(v)
        };
        match mode {
            0 => {
                let n = rd_u32(buf, &mut pos)? as usize;
                if pos + n * 4 > buf.len() {
                    bail!("truncated raw stream");
                }
                let mut v = Vec::with_capacity(n);
                for i in 0..n {
                    v.push(i32::from_le_bytes(buf[pos + i * 4..pos + i * 4 + 4].try_into().unwrap()));
                }
                pos += n * 4;
                Ok((Encoded::Raw(v), pos))
            }
            1 => {
                let tn = rd_u32(buf, &mut pos)? as usize;
                let count = rd_u32(buf, &mut pos)?;
                if pos + tn * 5 > buf.len() {
                    bail!("truncated huffman table");
                }
                let mut table = Vec::with_capacity(tn);
                for _ in 0..tn {
                    let sym = i32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap());
                    let len = buf[pos + 4];
                    pos += 5;
                    table.push((sym, len));
                }
                let bn = rd_u32(buf, &mut pos)? as usize;
                if pos + bn > buf.len() {
                    bail!("truncated huffman bits");
                }
                let bits = buf[pos..pos + bn].to_vec();
                pos += bn;
                Ok((Encoded::Huffman { table, count, bits }, pos))
            }
            m => bail!("unknown entropy mode {m}"),
        }
    }
}

/// Compute Huffman code lengths from frequencies (standard two-queue /
/// heap algorithm over a flat node arena).
fn code_lengths(freqs: &[(i32, u64)]) -> Vec<(i32, u8)> {
    let n = freqs.len();
    if n == 1 {
        return vec![(freqs[0].0, 1)];
    }
    // Node arena: (freq, parent). Leaves first.
    let mut freq: Vec<u64> = freqs.iter().map(|&(_, f)| f).collect();
    let mut parent = vec![usize::MAX; n];
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
        freq.iter().enumerate().map(|(i, &f)| Reverse((f, i))).collect();
    while heap.len() > 1 {
        let Reverse((fa, a)) = heap.pop().unwrap();
        let Reverse((fb, b)) = heap.pop().unwrap();
        let id = freq.len();
        freq.push(fa + fb);
        parent.push(usize::MAX);
        parent[a] = id;
        parent[b] = id;
        heap.push(Reverse((fa + fb, id)));
    }
    let mut out = Vec::with_capacity(n);
    for (i, &(sym, _)) in freqs.iter().enumerate() {
        let mut len = 0u32;
        let mut node = i;
        while parent[node] != usize::MAX {
            node = parent[node];
            len += 1;
        }
        out.push((sym, len.min(255) as u8));
    }
    out
}

/// Build canonical codes from (symbol, length) pairs sorted by
/// (length, symbol). Returns map symbol -> (code, length).
fn canonical_codes(table: &[(i32, u8)]) -> HashMap<i32, (u64, u8)> {
    let mut map = HashMap::with_capacity(table.len());
    let mut code = 0u64;
    let mut prev_len = 0u8;
    for &(sym, len) in table {
        if prev_len != 0 {
            code = (code + 1) << (len - prev_len);
        } else {
            code <<= len - prev_len;
        }
        map.insert(sym, (code, len));
        prev_len = len;
    }
    map
}

/// Exact serialized size of the Huffman encoding for a code stream with
/// this histogram (as produced by
/// [`crate::compress::quant::code_histogram`]), without emitting a
/// single bit — the rANS selector and the autotuner compare against it.
/// `None` when a depth overflow would force the raw fallback.
pub(crate) fn serialized_size_from_hist(hist: &[(i32, u64)]) -> Option<usize> {
    if hist.is_empty() {
        return None;
    }
    let lengths = code_lengths(hist);
    let mut total_bits = 0u64;
    let mut max_len = 0u8;
    for (&(_, count), &(_, len)) in hist.iter().zip(&lengths) {
        total_bits += count * len as u64;
        max_len = max_len.max(len);
    }
    if max_len > MAX_LEN {
        return None;
    }
    Some(1 + 4 + 4 + lengths.len() * 5 + 4 + ((total_bits + 7) / 8) as usize)
}

/// Encode a code stream. Chooses Huffman vs raw by serialized size.
pub fn encode(codes: &[i32]) -> Encoded {
    if codes.is_empty() {
        return Encoded::Raw(Vec::new());
    }
    encode_with_hist(codes, &code_histogram(codes))
}

/// [`encode`] against a precomputed histogram (as produced by
/// [`code_histogram`] from these same codes) — lets the entropy-stage
/// selector histogram a layer once, not once per candidate coder.
pub(crate) fn encode_with_hist(codes: &[i32], freqs: &[(i32, u64)]) -> Encoded {
    if codes.is_empty() {
        return Encoded::Raw(Vec::new());
    }
    let mut table = code_lengths(freqs);
    table.sort_unstable_by_key(|&(s, l)| (l, s));
    if table.last().map(|&(_, l)| l).unwrap_or(0) > MAX_LEN {
        return Encoded::Raw(codes.to_vec());
    }
    let codes_map = canonical_codes(&table);
    // Emission lookup: flat array for the fast range, HashMap otherwise.
    let flat_len = (2 * FAST_RADIUS + 1) as usize;
    let mut flat_codes: Vec<(u64, u8)> = vec![(0, 0); flat_len];
    for (&sym, &cl) in &codes_map {
        if (-FAST_RADIUS..=FAST_RADIUS).contains(&sym) {
            flat_codes[(sym + FAST_RADIUS) as usize] = cl;
        }
    }
    let mut w = BitWriter::new();
    if kernels::scalar_kernels() {
        // Scalar twin: one bit-queue write per symbol.
        for &c in codes {
            let (code, len) = if (-FAST_RADIUS..=FAST_RADIUS).contains(&c) {
                flat_codes[(c + FAST_RADIUS) as usize]
            } else {
                *codes_map.get(&c).expect("symbol in table")
            };
            w.put_bits(code, len);
        }
    } else {
        // Fast twin: batch codes into a local 64-bit accumulator and hand
        // the bit queue whole groups. `MAX_LEN ≤ 56 < 64` guarantees any
        // single code fits an empty accumulator, and concatenating the
        // same codes in the same order is byte-identical to the
        // per-symbol writes (MSB-first either way).
        let mut acc = 0u64;
        let mut nb = 0u8;
        for &c in codes {
            let (code, len) = if (-FAST_RADIUS..=FAST_RADIUS).contains(&c) {
                // SAFETY: the range check puts `c + FAST_RADIUS` in
                // `[0, 2 * FAST_RADIUS]`, and `flat_codes.len()` is
                // exactly `2 * FAST_RADIUS + 1`.
                unsafe { *flat_codes.get_unchecked((c + FAST_RADIUS) as usize) }
            } else {
                *codes_map.get(&c).expect("symbol in table")
            };
            if nb + len > 64 {
                w.put_bits(acc, nb);
                acc = 0;
                nb = 0;
            }
            acc = (acc << len) | code;
            nb += len;
        }
        if nb > 0 {
            w.put_bits(acc, nb);
        }
    }
    let enc = Encoded::Huffman { table, count: codes.len() as u32, bits: w.into_bytes() };
    let raw_size = 1 + 4 + codes.len() * 4;
    if enc.byte_size() >= raw_size {
        Encoded::Raw(codes.to_vec())
    } else {
        enc
    }
}

/// Windowed MSB-first bit source for the fast decoder: a 64-bit look-ahead
/// window refilled bytewise; reads past the end see zero bits (the encoder
/// zero-pads the final byte and `count` bounds the symbols).
struct FastBits<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u64,
    n: u8,
}

impl<'a> FastBits<'a> {
    fn new(buf: &'a [u8]) -> Self {
        FastBits { buf, pos: 0, acc: 0, n: 0 }
    }
    #[inline]
    fn refill(&mut self) {
        while self.n <= 56 {
            let byte = if self.pos < self.buf.len() {
                let b = self.buf[self.pos];
                self.pos += 1;
                b
            } else {
                0
            };
            self.acc |= (byte as u64) << (56 - self.n);
            self.n += 8;
            if self.pos >= self.buf.len() && self.n > 56 {
                break;
            }
        }
    }
    /// Fast-path refill: top the window up to ≥ 56 bits with one 8-byte
    /// word read (Giesen-style — `pos` only advances over *fully counted*
    /// bytes, so re-ORing the partially counted byte is idempotent: the
    /// same stream bits land on the same accumulator positions). Falls
    /// back to the bytewise loop within 8 bytes of the end.
    #[inline]
    fn refill_words(&mut self) {
        if self.pos + 8 <= self.buf.len() {
            // SAFETY: `pos + 8 ≤ buf.len()` was just checked, so the
            // 8-byte read starting at `pos` is fully in bounds.
            let w = u64::from_be_bytes(unsafe {
                *(self.buf.as_ptr().add(self.pos) as *const [u8; 8])
            });
            self.acc |= w >> self.n;
            self.pos += ((63 - self.n) >> 3) as usize;
            self.n |= 56;
        } else {
            self.refill();
        }
    }
    #[inline]
    fn peek(&self, k: u8) -> u64 {
        debug_assert!(k <= 56);
        if k == 0 {
            0
        } else {
            self.acc >> (64 - k)
        }
    }
    #[inline]
    fn consume(&mut self, k: u8) {
        self.acc <<= k;
        self.n = self.n.saturating_sub(k);
    }
    #[inline]
    fn take1(&mut self) -> u64 {
        self.refill();
        let b = self.peek(1);
        self.consume(1);
        b
    }
}

/// First-level LUT width for the fast decoder.
const LUT_BITS: usize = 12;

/// Decode back to the code stream.
pub fn decode(enc: &Encoded) -> anyhow::Result<Vec<i32>> {
    match enc {
        Encoded::Raw(v) => Ok(v.clone()),
        Encoded::Huffman { table, count, bits } => {
            // Canonical decode: per-length first-code and symbol offsets.
            if table.is_empty() {
                anyhow::bail!("empty huffman table");
            }
            let max_len = table.last().unwrap().1 as usize;
            if max_len == 0 || max_len > MAX_LEN as usize {
                anyhow::bail!("corrupt huffman table (max len {max_len})");
            }
            let mut first_code = vec![0u64; max_len + 2];
            let mut first_idx = vec![0usize; max_len + 2];
            let mut counts = vec![0usize; max_len + 2];
            for &(_, l) in table {
                if l as usize > max_len {
                    anyhow::bail!("unsorted huffman table");
                }
                counts[l as usize] += 1;
            }
            let mut code = 0u64;
            let mut idx = 0usize;
            for len in 1..=max_len {
                code <<= 1;
                first_code[len] = code;
                first_idx[len] = idx;
                code += counts[len] as u64;
                idx += counts[len];
            }
            // First-level LUT: prefix -> (symbol index, code length) for
            // codes at most LUT_BITS long (§Perf: ~3x decode speedup).
            let lut_bits = max_len.min(LUT_BITS);
            let mut lut: Vec<(u32, u8)> = vec![(u32::MAX, 0); 1 << lut_bits];
            {
                let mut code = 0u64;
                let mut prev_len = 0u8;
                for (i, &(_, len)) in table.iter().enumerate() {
                    if len < prev_len || len == 0 {
                        anyhow::bail!("unsorted or zero-length huffman table entry");
                    }
                    if prev_len != 0 {
                        code = (code + 1) << (len - prev_len);
                    } else {
                        code <<= len - prev_len;
                    }
                    prev_len = len;
                    if (len as usize) <= lut_bits {
                        let shift = lut_bits - len as usize;
                        let base = (code << shift) as usize;
                        for e in lut.iter_mut().skip(base).take(1 << shift) {
                            *e = (i as u32, len);
                        }
                    }
                }
            }
            // Long-code fallback (> lut_bits bits): per-bit canonical.
            // Cold in both twins — LUT misses are rare by construction.
            let long_code = |fb: &mut FastBits, out: &mut Vec<i32>| -> anyhow::Result<()> {
                let mut code = 0u64;
                let mut l = 0usize;
                loop {
                    code = (code << 1) | fb.take1();
                    l += 1;
                    if l > max_len {
                        anyhow::bail!("invalid huffman code");
                    }
                    if counts[l] > 0
                        && code >= first_code[l]
                        && code < first_code[l] + counts[l] as u64
                    {
                        let sym_idx = first_idx[l] + (code - first_code[l]) as usize;
                        out.push(table[sym_idx].0);
                        return Ok(());
                    }
                }
            };
            let mut fb = FastBits::new(bits);
            let mut out = Vec::with_capacity(*count as usize);
            if kernels::scalar_kernels() {
                // Scalar twin: bytewise window refill, checked indexing.
                for _ in 0..*count {
                    fb.refill();
                    let (sym_idx, len) = lut[fb.peek(lut_bits as u8) as usize];
                    if len != 0 {
                        fb.consume(len);
                        out.push(table[sym_idx as usize].0);
                        continue;
                    }
                    long_code(&mut fb, &mut out)?;
                }
            } else {
                // Fast twin: word-at-a-time refill, unchecked LUT/table
                // indexing in the hot loop.
                for _ in 0..*count {
                    fb.refill_words();
                    // SAFETY: `peek(k)` returns `acc >> (64 - k)`, which is
                    // `< 2^lut_bits = lut.len()` for `k = lut_bits ≥ 1`.
                    let (sym_idx, len) = unsafe {
                        *lut.get_unchecked(fb.peek(lut_bits as u8) as usize)
                    };
                    if len != 0 {
                        fb.consume(len);
                        // SAFETY: LUT entries with `len != 0` were written
                        // exactly once in the build loop above with
                        // `sym_idx = i < table.len()`; the `u32::MAX`
                        // sentinel entries carry `len == 0` and never
                        // reach this branch.
                        out.push(unsafe { table.get_unchecked(sym_idx as usize) }.0);
                        continue;
                    }
                    long_code(&mut fb, &mut out)?;
                }
            }
            Ok(out)
        }
    }
}

/// Convenience: serialized-encode straight to bytes.
pub fn encode_to_bytes(codes: &[i32]) -> Vec<u8> {
    let enc = encode(codes);
    let mut out = Vec::with_capacity(enc.byte_size());
    enc.write_to(&mut out);
    out
}

/// Convenience: decode from bytes, returning (codes, bytes consumed).
pub fn decode_from_bytes(buf: &[u8]) -> anyhow::Result<(Vec<i32>, usize)> {
    let (enc, used) = Encoded::read_from(buf)?;
    Ok((decode(&enc)?, used))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_simple() {
        let codes = vec![0, 0, 0, 1, -1, 0, 2, 0, 0, -1];
        let enc = encode(&codes);
        assert_eq!(decode(&enc).unwrap(), codes);
    }

    #[test]
    fn roundtrip_single_symbol() {
        let codes = vec![5; 1000];
        let enc = encode(&codes);
        assert_eq!(decode(&enc).unwrap(), codes);
        // 1000 identical symbols should compress massively.
        assert!(enc.byte_size() < 200, "size={}", enc.byte_size());
    }

    #[test]
    fn roundtrip_empty() {
        let enc = encode(&[]);
        assert_eq!(decode(&enc).unwrap(), Vec::<i32>::new());
    }

    #[test]
    fn skewed_stream_beats_raw() {
        let mut rng = Rng::new(3);
        let codes: Vec<i32> = (0..100_000)
            .map(|_| {
                let g = rng.gauss() * 1.5;
                g.round() as i32
            })
            .collect();
        let enc = encode(&codes);
        let raw = codes.len() * 4;
        assert!(enc.byte_size() < raw / 4, "huffman {} vs raw {}", enc.byte_size(), raw);
        assert_eq!(decode(&enc).unwrap(), codes);
    }

    #[test]
    fn serialization_roundtrip() {
        let codes = vec![3, -7, 3, 3, 0, 0, 12345, -1];
        let bytes = encode_to_bytes(&codes);
        let (got, used) = decode_from_bytes(&bytes).unwrap();
        assert_eq!(got, codes);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn truncated_stream_errors() {
        let codes = vec![1, 2, 3, 1, 2, 3, 1, 1, 1];
        let bytes = encode_to_bytes(&codes);
        assert!(decode_from_bytes(&bytes[..bytes.len() / 2]).is_err() || bytes.len() < 2);
    }

    #[test]
    fn property_roundtrip_random_streams() {
        prop::check("huffman roundtrip", 100, |rng| {
            let n = prop::arb_len(rng, 5000);
            let spread = 1 + rng.next_below(1000) as i32;
            let codes: Vec<i32> =
                (0..n).map(|_| rng.next_below(spread as usize * 2) as i32 - spread).collect();
            let bytes = encode_to_bytes(&codes);
            let (got, used) = decode_from_bytes(&bytes).map_err(|e| e.to_string())?;
            if got != codes {
                return Err("mismatch".into());
            }
            if used != bytes.len() {
                return Err(format!("used {used} != len {}", bytes.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn size_estimate_is_exact() {
        // The rANS selector trusts this estimate to the byte: whenever the
        // encoder picks Huffman, the estimate must equal the real size.
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let n = 1 + rng.next_below(3000);
            let spread = 1 + rng.next_below(200) as i32;
            let codes: Vec<i32> =
                (0..n).map(|_| rng.next_below(spread as usize * 2) as i32 - spread).collect();
            let est = serialized_size_from_hist(&crate::compress::quant::code_histogram(&codes));
            let raw_size = 1 + 4 + codes.len() * 4;
            match encode(&codes) {
                enc @ Encoded::Huffman { .. } => assert_eq!(est.unwrap(), enc.byte_size()),
                Encoded::Raw(_) => {
                    if let Some(e) = est {
                        assert!(e >= raw_size);
                    }
                }
            }
        }
    }

    #[test]
    fn includes_escape_marker_symbol() {
        let codes = vec![i32::MIN, 0, 0, i32::MIN, 7];
        let enc = encode(&codes);
        assert_eq!(decode(&enc).unwrap(), codes);
    }

    #[test]
    fn scalar_and_fast_twins_agree_bytewise() {
        prop::check("huffman scalar==fast", 60, |rng| {
            let n = prop::arb_len(rng, 6000);
            let spread = 1 + rng.next_below(2000) as i32;
            let codes: Vec<i32> =
                (0..n).map(|_| rng.next_below(spread as usize * 2) as i32 - spread).collect();
            let fast = encode_to_bytes(&codes);
            let slow = kernels::with_scalar_kernels(|| encode_to_bytes(&codes));
            if fast != slow {
                return Err("encoded bytes diverge".into());
            }
            let (df, _) = decode_from_bytes(&fast).map_err(|e| e.to_string())?;
            let ds = kernels::with_scalar_kernels(|| decode_from_bytes(&fast).map(|x| x.0))
                .map_err(|e| e.to_string())?;
            if df != codes || ds != codes {
                return Err("decode mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    #[cfg_attr(miri, ignore = "large stream; kernel coverage comes from the prop tests")]
    fn deep_codes_hit_long_fallback_in_both_twins() {
        // Fibonacci-weighted frequencies force canonical depths well past
        // LUT_BITS, exercising the per-bit fallback of both decode twins.
        let mut counts = vec![1u64, 1];
        for i in 2..25 {
            counts.push(counts[i - 1] + counts[i - 2]);
        }
        let mut codes = Vec::new();
        for (sym, &cnt) in counts.iter().enumerate() {
            for _ in 0..cnt {
                codes.push(sym as i32 - 12);
            }
        }
        let enc = encode(&codes);
        if let Encoded::Huffman { table, .. } = &enc {
            assert!(table.last().unwrap().1 as usize > LUT_BITS, "distribution not deep enough");
        } else {
            panic!("expected huffman mode");
        }
        assert_eq!(decode(&enc).unwrap(), codes);
        kernels::with_scalar_kernels(|| assert_eq!(decode(&enc).unwrap(), codes));
    }
}
