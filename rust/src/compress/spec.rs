//! `CodecSpec` — the string-parsable, serializable codec descriptor and
//! registry that replaced the positional `make_codec(name, eb, bits)`
//! factory.
//!
//! # Grammar
//!
//! ```text
//! spec    := "ef" "(" spec ")" | family [ ":" kv ("," kv)* ]
//! kv      := key "=" value
//! eb      := "rel"<f64> | "abs"<f64> | <f64>        (bare value = REL)
//! ```
//!
//! Families and their keys:
//!
//! | family                    | keys                                      |
//! |---------------------------|-------------------------------------------|
//! | `fedgec` (alias `ours`)   | `eb`, `beta`, `tau`, `full_batch`, `autotune`, `ec`, `backend`, `pred`, `sign` |
//! | `sz3`                     | `eb`, `ec`, `backend`                     |
//! | `qsgd`                    | `bits`, `seed`                            |
//! | `topk`                    | `k`                                       |
//! | `raw` (alias `none`)      | —                                         |
//! | `topk+eblc`               | `k`, `eb`                                 |
//! | `ef(<spec>)` (aliases `ef-topk`, `ef-qsgd`) | wraps any inner spec    |
//!
//! Examples: `fedgec:eb=rel1e-2,beta=0.9`, `fedgec:eb=rel1e-2,ec=rans`,
//! `fedgec:pred=auto,sign=none`, `qsgd:bits=5`, `topk:k=0.05`,
//! `ef(qsgd:bits=5)`.
//!
//! The `pred` key selects the magnitude predictor
//! (`ema[:<beta>] | last | zero | auto`, see
//! [`super::predictor::magnitude::MAG_REGISTRY`]): `ema` is the
//! implicit default (its `:<beta>` suffix sets β, and a bare `pred=ema`
//! keeps the one struct-level default β so grammar and
//! `FedgecConfig::default` can never drift); `auto` races the fixed
//! predictors per layer each round. The `sign` key selects the sign
//! policy (`auto | osc | kernel | none`); `auto` (the default) resolves
//! through the `full_batch` regime flag. Defaults are omitted from the
//! canonical form.
//!
//! The `ec` key selects the stage-3 entropy coder for the entropy-coded
//! families (`huff` | `rans` | `raw`, see [`super::entropy`]); `huff` is
//! the byte-compatible default and is omitted from the canonical form.
//! The `backend` key selects the stage-4 lossless backend
//! (`zstd[:level]` | `deflate` | `ownlz` | `none`, see
//! [`super::lossless::Backend::from_name`]); `zstd` (level 3) is the
//! default and is likewise omitted.
//!
//! `Display` renders the canonical form and `parse` accepts it back
//! (`parse(spec.to_string()) == spec`), which is the serialized
//! representation stored in JSON configs and CLI flags. Unspecified keys
//! fall back to [`SpecDefaults`], so legacy bare names (`"sz3"`,
//! `"qsgd"`, …) still resolve with contextual defaults — the deprecated
//! [`crate::baselines::make_codec`] shim forwards here.

use std::fmt;

use super::entropy::EntropyCoder;
use super::lossless::Backend;
use super::pipeline::{FedgecCodec, FedgecConfig};
use super::predictor::magnitude::{MagnitudeSel, DEFAULT_BETA};
use super::predictor::sign::SignSel;
use super::predictor::PredictorSpec;
use super::quant::ErrorBound;
use super::GradientCodec;
use crate::baselines::composed::{ErrorFeedback, SparsifiedEblc};
use crate::baselines::qsgd::QsgdCodec;
use crate::baselines::sz3::{Sz3Codec, Sz3Config};
use crate::baselines::topk::TopKCodec;
use crate::baselines::RawCodec;

/// Contextual defaults for keys a spec string leaves out.
#[derive(Debug, Clone)]
pub struct SpecDefaults {
    pub error_bound: ErrorBound,
    pub qsgd_bits: u8,
    pub qsgd_seed: u64,
    pub beta: f32,
    pub tau: f64,
    pub full_batch: bool,
    pub autotune: bool,
    pub topk: f64,
    pub entropy: EntropyCoder,
    pub backend: Backend,
    pub pred: MagnitudeSel,
    pub sign: SignSel,
}

impl Default for SpecDefaults {
    fn default() -> Self {
        SpecDefaults {
            error_bound: ErrorBound::Rel(1e-2),
            qsgd_bits: 5,
            qsgd_seed: 0,
            beta: DEFAULT_BETA,
            tau: 0.5,
            full_batch: false,
            autotune: false,
            topk: 0.05,
            entropy: EntropyCoder::Huffman,
            backend: Backend::default(),
            pred: MagnitudeSel::Ema,
            sign: SignSel::Auto,
        }
    }
}

impl SpecDefaults {
    /// Defaults for a REL error bound, with the paper's §5.3 QSGD
    /// bit-width pairing.
    pub fn with_rel_eb(eb: f64) -> Self {
        SpecDefaults {
            error_bound: ErrorBound::Rel(eb),
            qsgd_bits: crate::baselines::qsgd_bits_for_bound(eb),
            ..Default::default()
        }
    }
}

/// A fully-resolved codec descriptor: everything needed to build one
/// side of the codec pipe.
#[derive(Debug, Clone, PartialEq)]
pub enum CodecSpec {
    /// The paper's gradient-aware EBLC.
    Fedgec {
        eb: ErrorBound,
        beta: f32,
        tau: f64,
        full_batch: bool,
        autotune: bool,
        ec: EntropyCoder,
        backend: Backend,
        /// Magnitude predictor (key `pred`): `ema` (implicit default,
        /// seed-byte-compatible frames) | `last` | `zero` | `auto`.
        pred: MagnitudeSel,
        /// Sign policy (key `sign`): `auto` | `osc` | `kernel` | `none`.
        sign: SignSel,
    },
    /// Generic Lorenzo/interpolation EBLC (Table 4 comparator).
    Sz3 { eb: ErrorBound, ec: EntropyCoder, backend: Backend },
    /// Stochastic quantization (not error-bounded).
    Qsgd { bits: u8, seed: u64 },
    /// TopK sparsification.
    TopK { k: f64 },
    /// Identity / uncompressed.
    Raw,
    /// TopK upstream, EBLC quantization of kept values (§7.1).
    SparseEblc { k: f64, eb: ErrorBound },
    /// Error-feedback wrapper around any inner codec.
    ErrorFeedback(Box<CodecSpec>),
}

/// One registry entry: a codec family the parser knows how to build.
#[derive(Debug, Clone, Copy)]
pub struct CodecFamily {
    /// Canonical family name (the spec grammar's `family` token).
    pub family: &'static str,
    /// Accepted aliases (legacy `make_codec` names included).
    pub aliases: &'static [&'static str],
    /// Example spec string.
    pub example: &'static str,
    /// One-line description.
    pub about: &'static str,
}

/// Every codec family in the repo (ours + baselines + compositions).
pub const REGISTRY: &[CodecFamily] = &[
    CodecFamily {
        family: "fedgec",
        aliases: &["ours"],
        example: "fedgec:eb=rel1e-2,beta=0.9,tau=0.5,pred=auto,sign=kernel,ec=rans",
        about: "gradient-aware EBLC (the paper's codec); pred=ema|last|zero|auto, \
                sign=auto|osc|kernel|none, ec=huff|rans|rans4|rans8|raw",
    },
    CodecFamily {
        family: "sz3",
        aliases: &[],
        example: "sz3:eb=rel1e-2,ec=huff",
        about: "generic Lorenzo/interpolation EBLC baseline",
    },
    CodecFamily {
        family: "qsgd",
        aliases: &[],
        example: "qsgd:bits=5",
        about: "stochastic quantization (QSGD)",
    },
    CodecFamily {
        family: "topk",
        aliases: &[],
        example: "topk:k=0.05",
        about: "TopK sparsification",
    },
    CodecFamily {
        family: "raw",
        aliases: &["none"],
        example: "raw",
        about: "identity (uncompressed) codec",
    },
    CodecFamily {
        family: "topk+eblc",
        aliases: &["sparse-eblc"],
        example: "topk+eblc:k=0.05,eb=rel1e-2",
        about: "TopK upstream + error-bounded quantization of kept values",
    },
    CodecFamily {
        family: "ef",
        aliases: &["ef-topk", "ef-qsgd"],
        example: "ef(qsgd:bits=5)",
        about: "error-feedback wrapper around any inner codec",
    },
];

fn parse_f64(key: &str, v: &str) -> crate::Result<f64> {
    v.parse::<f64>().map_err(|_| anyhow::anyhow!("codec spec: bad number for {key}: '{v}'"))
}

fn parse_bool(key: &str, v: &str) -> crate::Result<bool> {
    match v {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        _ => anyhow::bail!("codec spec: bad bool for {key}: '{v}'"),
    }
}

fn parse_eb(v: &str) -> crate::Result<ErrorBound> {
    let eb = if let Some(rest) = v.strip_prefix("rel") {
        ErrorBound::Rel(parse_f64("eb", rest)?)
    } else if let Some(rest) = v.strip_prefix("abs") {
        ErrorBound::Abs(parse_f64("eb", rest)?)
    } else {
        ErrorBound::Rel(parse_f64("eb", v)?)
    };
    // A zero/negative/non-finite bound would propagate into the
    // quantizer as a nonsense Δ; reject it at the parse boundary.
    let (ErrorBound::Rel(m) | ErrorBound::Abs(m)) = eb;
    anyhow::ensure!(
        m.is_finite() && m > 0.0,
        "codec spec: eb must be a finite positive number, got '{v}'"
    );
    Ok(eb)
}

fn parse_ec(v: &str) -> crate::Result<EntropyCoder> {
    EntropyCoder::from_name(v).ok_or_else(|| {
        anyhow::anyhow!("codec spec: unknown entropy coder '{v}' (huff|rans|rans4|rans8|raw)")
    })
}

fn parse_backend(v: &str) -> crate::Result<Backend> {
    Backend::from_name(v).map_err(|e| anyhow::anyhow!("codec spec: {e}"))
}

fn fmt_eb(eb: &ErrorBound) -> String {
    match eb {
        ErrorBound::Rel(v) => format!("rel{v}"),
        ErrorBound::Abs(v) => format!("abs{v}"),
    }
}

/// Split `key=value` pairs out of the params section.
fn parse_params(params: &str) -> crate::Result<Vec<(&str, &str)>> {
    if params.is_empty() {
        return Ok(Vec::new());
    }
    params
        .split(',')
        .map(|kv| {
            kv.split_once('=')
                .map(|(k, v)| (k.trim(), v.trim()))
                .ok_or_else(|| anyhow::anyhow!("codec spec: expected key=value, got '{kv}'"))
        })
        .collect()
}

impl CodecSpec {
    /// Parse a spec string with the stock defaults.
    pub fn parse(s: &str) -> crate::Result<CodecSpec> {
        Self::parse_with(s, &SpecDefaults::default())
    }

    /// Parse a spec string, resolving omitted keys from `d`.
    pub fn parse_with(s: &str, d: &SpecDefaults) -> crate::Result<CodecSpec> {
        let s = s.trim();
        anyhow::ensure!(!s.is_empty(), "empty codec spec");
        // Wrapper form: ef(<inner spec>).
        if let Some(inner) = s.strip_prefix("ef(") {
            let inner = inner
                .strip_suffix(')')
                .ok_or_else(|| anyhow::anyhow!("codec spec: unclosed ef( in '{s}'"))?;
            let inner = Self::parse_with(inner, d)?;
            anyhow::ensure!(
                inner.stateless(),
                "codec spec: ef(...) requires a stateless inner codec, got '{inner}' \
                 (error feedback scratch-decodes on the encoder side, which would \
                 desynchronize a cross-round-stateful inner's client/server mirror)"
            );
            return Ok(CodecSpec::ErrorFeedback(Box::new(inner)));
        }
        let (family, params) = match s.split_once(':') {
            Some((f, p)) => (f.trim(), p),
            None => (s, ""),
        };
        let kvs = parse_params(params)?;
        let unknown = |key: &str| anyhow::anyhow!("codec spec: unknown key '{key}' for {family}");
        match family {
            "fedgec" | "ours" => {
                let mut eb = d.error_bound;
                let mut beta = d.beta;
                let mut tau = d.tau;
                let mut full_batch = d.full_batch;
                let mut autotune = d.autotune;
                let mut ec = d.entropy;
                let mut backend = d.backend;
                let mut pred = d.pred;
                let mut sign = d.sign;
                for (k, v) in kvs {
                    match k {
                        "eb" => eb = parse_eb(v)?,
                        "beta" => beta = parse_f64(k, v)? as f32,
                        "tau" => tau = parse_f64(k, v)?,
                        "full_batch" => full_batch = parse_bool(k, v)?,
                        "autotune" => autotune = parse_bool(k, v)?,
                        "ec" => ec = parse_ec(v)?,
                        "backend" => backend = parse_backend(v)?,
                        // `pred=ema:<beta>` doubles as a β setter (last
                        // of it and `beta=` wins); a bare `pred=ema`
                        // keeps the shared struct-level default, so the
                        // grammar can never drift from
                        // `FedgecConfig::default().beta`.
                        "pred" => {
                            if let Some(rest) = v.strip_prefix("ema:") {
                                pred = MagnitudeSel::Ema;
                                beta = parse_f64("pred", rest)? as f32;
                            } else {
                                pred = MagnitudeSel::from_name(v).ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "codec spec: unknown predictor '{v}' \
                                         (ema[:<beta>]|last|zero|auto)"
                                    )
                                })?;
                            }
                        }
                        "sign" => {
                            sign = SignSel::from_name(v).ok_or_else(|| {
                                anyhow::anyhow!(
                                    "codec spec: unknown sign policy '{v}' \
                                     (auto|osc|kernel|none)"
                                )
                            })?;
                        }
                        _ => return Err(unknown(k)),
                    }
                }
                Ok(CodecSpec::Fedgec {
                    eb,
                    beta,
                    tau,
                    full_batch,
                    autotune,
                    ec,
                    backend,
                    pred,
                    sign,
                })
            }
            "sz3" => {
                let mut eb = d.error_bound;
                let mut ec = d.entropy;
                let mut backend = d.backend;
                for (k, v) in kvs {
                    match k {
                        "eb" => eb = parse_eb(v)?,
                        "ec" => ec = parse_ec(v)?,
                        "backend" => backend = parse_backend(v)?,
                        _ => return Err(unknown(k)),
                    }
                }
                Ok(CodecSpec::Sz3 { eb, ec, backend })
            }
            "qsgd" => {
                let mut bits = d.qsgd_bits;
                let mut seed = d.qsgd_seed;
                for (k, v) in kvs {
                    match k {
                        "bits" => {
                            bits = v.parse::<u8>().map_err(|_| {
                                anyhow::anyhow!("codec spec: bad integer for bits: '{v}'")
                            })?
                        }
                        "seed" => {
                            seed = v.parse::<u64>().map_err(|_| {
                                anyhow::anyhow!("codec spec: bad integer for seed: '{v}'")
                            })?
                        }
                        _ => return Err(unknown(k)),
                    }
                }
                anyhow::ensure!((1..=16).contains(&bits), "qsgd bits {bits} outside 1..=16");
                Ok(CodecSpec::Qsgd { bits, seed })
            }
            "topk" => {
                let mut k_frac = d.topk;
                for (k, v) in kvs {
                    match k {
                        "k" => k_frac = parse_f64(k, v)?,
                        _ => return Err(unknown(k)),
                    }
                }
                anyhow::ensure!(k_frac > 0.0 && k_frac <= 1.0, "topk k {k_frac} outside (0,1]");
                Ok(CodecSpec::TopK { k: k_frac })
            }
            "raw" | "none" => {
                anyhow::ensure!(kvs.is_empty(), "codec spec: raw takes no params");
                Ok(CodecSpec::Raw)
            }
            "topk+eblc" | "sparse-eblc" => {
                let mut k_frac = d.topk;
                let mut eb = d.error_bound;
                for (k, v) in kvs {
                    match k {
                        "k" => k_frac = parse_f64(k, v)?,
                        "eb" => eb = parse_eb(v)?,
                        _ => return Err(unknown(k)),
                    }
                }
                anyhow::ensure!(k_frac > 0.0 && k_frac <= 1.0, "topk k {k_frac} outside (0,1]");
                Ok(CodecSpec::SparseEblc { k: k_frac, eb })
            }
            // Legacy composed names from the old factory (no params — the
            // parameterized form is ef(<inner spec>)).
            "ef-topk" => {
                anyhow::ensure!(
                    kvs.is_empty(),
                    "codec spec: 'ef-topk' takes no params; use ef(topk:k=...)"
                );
                Ok(CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK { k: d.topk })))
            }
            "ef-qsgd" => {
                anyhow::ensure!(
                    kvs.is_empty(),
                    "codec spec: 'ef-qsgd' takes no params; use ef(qsgd:bits=...)"
                );
                Ok(CodecSpec::ErrorFeedback(Box::new(CodecSpec::Qsgd {
                    bits: d.qsgd_bits,
                    seed: d.qsgd_seed,
                })))
            }
            "ef" => anyhow::bail!(
                "codec spec: 'ef' is a wrapper — use the form ef(<inner spec>), \
                 e.g. ef(qsgd:bits=5)"
            ),
            _ => anyhow::bail!(
                "unknown codec family '{family}' (known: {})",
                REGISTRY
                    .iter()
                    .map(|f| f.family)
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        }
    }

    /// Canonical family name of this spec.
    pub fn family(&self) -> &'static str {
        match self {
            CodecSpec::Fedgec { .. } => "fedgec",
            CodecSpec::Sz3 { .. } => "sz3",
            CodecSpec::Qsgd { .. } => "qsgd",
            CodecSpec::TopK { .. } => "topk",
            CodecSpec::Raw => "raw",
            CodecSpec::SparseEblc { .. } => "topk+eblc",
            CodecSpec::ErrorFeedback(_) => "ef",
        }
    }

    /// Build one side of the codec pipe (client compressor or its server
    /// mirror — they are symmetric objects).
    pub fn build(&self) -> Box<dyn GradientCodec> {
        match self {
            CodecSpec::Fedgec { eb, beta, tau, full_batch, autotune, ec, backend, pred, sign } => {
                Box::new(FedgecCodec::new(FedgecConfig {
                    error_bound: *eb,
                    beta: *beta,
                    tau: *tau,
                    full_batch: *full_batch,
                    autotune: *autotune,
                    entropy: *ec,
                    backend: *backend,
                    predictor: PredictorSpec { mag: *pred, sign: *sign },
                    ..Default::default()
                }))
            }
            CodecSpec::Sz3 { eb, ec, backend } => Box::new(Sz3Codec::new(Sz3Config {
                error_bound: *eb,
                entropy: *ec,
                backend: *backend,
                ..Default::default()
            })),
            CodecSpec::Qsgd { bits, seed } => Box::new(QsgdCodec::new(*bits, *seed)),
            CodecSpec::TopK { k } => Box::new(TopKCodec::new(*k)),
            CodecSpec::Raw => Box::new(RawCodec),
            CodecSpec::SparseEblc { k, eb } => Box::new(SparsifiedEblc::new(*k, *eb)),
            CodecSpec::ErrorFeedback(inner) => Box::new(ErrorFeedback::new(inner.build())),
        }
    }

    /// Build the server-side **stateless decode engine** for this spec.
    /// Unlike [`CodecSpec::build`] (one stateful object per peer), one
    /// engine serves every client: per-client predictor state is fetched
    /// from a [`crate::compress::store::StateStore`] and passed into each
    /// decode call. Error feedback is a client-side mechanism, so its
    /// engine is simply the inner codec's engine.
    pub fn build_engine(&self) -> Box<dyn crate::compress::engine::CodecEngine> {
        use crate::compress::engine::StatelessEngine;
        use crate::compress::pipeline::FedgecEngine;
        match self {
            CodecSpec::Fedgec { eb, beta, tau, full_batch, autotune, ec, backend, pred, sign } => {
                Box::new(FedgecEngine::new(FedgecConfig {
                    error_bound: *eb,
                    beta: *beta,
                    tau: *tau,
                    full_batch: *full_batch,
                    autotune: *autotune,
                    entropy: *ec,
                    backend: *backend,
                    predictor: PredictorSpec { mag: *pred, sign: *sign },
                    ..Default::default()
                }))
            }
            CodecSpec::Sz3 { eb, ec, backend } => Box::new(Sz3Codec::new(Sz3Config {
                error_bound: *eb,
                entropy: *ec,
                backend: *backend,
                ..Default::default()
            })),
            CodecSpec::ErrorFeedback(inner) => inner.build_engine(),
            other => Box::new(StatelessEngine::new(other.build())),
        }
    }

    /// One default spec per registry family (used by the exhaustive
    /// round-trip property tests). Error-feedback appears with both inner
    /// codecs the old factory shipped.
    pub fn registry_specs(d: &SpecDefaults) -> Vec<CodecSpec> {
        vec![
            CodecSpec::Fedgec {
                eb: d.error_bound,
                beta: d.beta,
                tau: d.tau,
                full_batch: d.full_batch,
                autotune: d.autotune,
                ec: d.entropy,
                backend: d.backend,
                pred: d.pred,
                sign: d.sign,
            },
            CodecSpec::Sz3 { eb: d.error_bound, ec: d.entropy, backend: d.backend },
            CodecSpec::Qsgd { bits: d.qsgd_bits, seed: d.qsgd_seed },
            CodecSpec::TopK { k: d.topk },
            CodecSpec::Raw,
            CodecSpec::SparseEblc { k: d.topk, eb: d.error_bound },
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::TopK { k: d.topk })),
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::Qsgd {
                bits: d.qsgd_bits,
                seed: d.qsgd_seed,
            })),
            // rANS twins of the entropy-coded families (same predictor
            // path, different stage-3 coder) — so the registry-wide
            // property suites exercise `ec=rans` end to end.
            CodecSpec::Fedgec {
                eb: d.error_bound,
                beta: d.beta,
                tau: d.tau,
                full_batch: d.full_batch,
                autotune: d.autotune,
                ec: EntropyCoder::Rans,
                backend: d.backend,
                pred: d.pred,
                sign: d.sign,
            },
            CodecSpec::Sz3 { eb: d.error_bound, ec: EntropyCoder::Rans, backend: d.backend },
            // Wide-interleave rANS twins (`ec=rans4` / `ec=rans8`): the
            // same size race as `ec=rans`, their own wire mode bytes —
            // registry membership drives the scalar↔fast and
            // frame-roundtrip property suites over the new lane widths.
            CodecSpec::Fedgec {
                eb: d.error_bound,
                beta: d.beta,
                tau: d.tau,
                full_batch: d.full_batch,
                autotune: d.autotune,
                ec: EntropyCoder::Rans4,
                backend: d.backend,
                pred: d.pred,
                sign: d.sign,
            },
            CodecSpec::Fedgec {
                eb: d.error_bound,
                beta: d.beta,
                tau: d.tau,
                full_batch: d.full_batch,
                autotune: d.autotune,
                ec: EntropyCoder::Rans8,
                backend: d.backend,
                pred: d.pred,
                sign: d.sign,
            },
            // Predictor-API twins: the per-layer race and a fixed
            // non-EMA predictor with the sign stage off — so the
            // registry-wide suites drive self-describing (v3) frames
            // end to end.
            CodecSpec::Fedgec {
                eb: d.error_bound,
                beta: d.beta,
                tau: d.tau,
                full_batch: d.full_batch,
                autotune: d.autotune,
                ec: d.entropy,
                backend: d.backend,
                pred: MagnitudeSel::Auto,
                sign: d.sign,
            },
            CodecSpec::Fedgec {
                eb: d.error_bound,
                beta: d.beta,
                tau: d.tau,
                full_batch: d.full_batch,
                autotune: d.autotune,
                ec: d.entropy,
                backend: d.backend,
                pred: MagnitudeSel::Last,
                sign: SignSel::None,
            },
        ]
    }

    /// Whether reconstructions carry a per-element error bound.
    pub fn error_bounded(&self) -> bool {
        matches!(
            self,
            CodecSpec::Fedgec { .. } | CodecSpec::Sz3 { .. } | CodecSpec::Raw
        )
    }

    /// Whether the codec carries no cross-round predictor state (a
    /// requirement for the `ef(...)` wrapper, whose encoder-side scratch
    /// decode would desynchronize a stateful inner's server mirror).
    pub fn stateless(&self) -> bool {
        match self {
            CodecSpec::Fedgec { .. } | CodecSpec::ErrorFeedback(_) => false,
            CodecSpec::Sz3 { .. }
            | CodecSpec::Qsgd { .. }
            | CodecSpec::TopK { .. }
            | CodecSpec::Raw
            | CodecSpec::SparseEblc { .. } => true,
        }
    }
}

impl fmt::Display for CodecSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecSpec::Fedgec { eb, beta, tau, full_batch, autotune, ec, backend, pred, sign } => {
                write!(f, "fedgec:eb={},beta={beta},tau={tau}", fmt_eb(eb))?;
                if *pred != MagnitudeSel::Ema {
                    write!(f, ",pred={}", pred.name())?;
                }
                if *sign != SignSel::Auto {
                    write!(f, ",sign={}", sign.name())?;
                }
                if *full_batch {
                    write!(f, ",full_batch=true")?;
                }
                if *autotune {
                    write!(f, ",autotune=true")?;
                }
                if *ec != EntropyCoder::Huffman {
                    write!(f, ",ec={}", ec.name())?;
                }
                if *backend != Backend::default() {
                    write!(f, ",backend={}", backend.spec_name())?;
                }
                Ok(())
            }
            CodecSpec::Sz3 { eb, ec, backend } => {
                write!(f, "sz3:eb={}", fmt_eb(eb))?;
                if *ec != EntropyCoder::Huffman {
                    write!(f, ",ec={}", ec.name())?;
                }
                if *backend != Backend::default() {
                    write!(f, ",backend={}", backend.spec_name())?;
                }
                Ok(())
            }
            CodecSpec::Qsgd { bits, seed } => {
                write!(f, "qsgd:bits={bits}")?;
                if *seed != 0 {
                    write!(f, ",seed={seed}")?;
                }
                Ok(())
            }
            CodecSpec::TopK { k } => write!(f, "topk:k={k}"),
            CodecSpec::Raw => write!(f, "raw"),
            CodecSpec::SparseEblc { k, eb } => {
                write!(f, "topk+eblc:k={k},eb={}", fmt_eb(eb))
            }
            CodecSpec::ErrorFeedback(inner) => write!(f, "ef({inner})"),
        }
    }
}

impl std::str::FromStr for CodecSpec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        CodecSpec::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_forms() {
        let s = CodecSpec::parse("fedgec:eb=rel1e-2,beta=0.8,tau=0.6,autotune=true").unwrap();
        match s {
            CodecSpec::Fedgec { eb, beta, tau, full_batch, autotune, ec, backend, pred, sign } => {
                assert_eq!(eb, ErrorBound::Rel(1e-2));
                assert!((beta - 0.8).abs() < 1e-6);
                assert!((tau - 0.6).abs() < 1e-12);
                assert!(!full_batch);
                assert!(autotune);
                assert_eq!(ec, EntropyCoder::Huffman);
                assert_eq!(backend, Backend::default());
                assert_eq!(pred, MagnitudeSel::Ema);
                assert_eq!(sign, SignSel::Auto);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(
            CodecSpec::parse("sz3:eb=abs0.001").unwrap(),
            CodecSpec::Sz3 {
                eb: ErrorBound::Abs(0.001),
                ec: EntropyCoder::Huffman,
                backend: Backend::default()
            }
        );
        assert_eq!(
            CodecSpec::parse("qsgd:bits=8,seed=7").unwrap(),
            CodecSpec::Qsgd { bits: 8, seed: 7 }
        );
        assert_eq!(CodecSpec::parse("topk:k=0.1").unwrap(), CodecSpec::TopK { k: 0.1 });
        assert_eq!(
            CodecSpec::parse("ef(qsgd:bits=5)").unwrap(),
            CodecSpec::ErrorFeedback(Box::new(CodecSpec::Qsgd { bits: 5, seed: 0 }))
        );
    }

    #[test]
    fn bare_eb_is_rel() {
        assert_eq!(
            CodecSpec::parse("sz3:eb=0.03").unwrap(),
            CodecSpec::Sz3 {
                eb: ErrorBound::Rel(0.03),
                ec: EntropyCoder::Huffman,
                backend: Backend::default()
            }
        );
    }

    #[test]
    fn backend_key_parses_and_roundtrips() {
        // backend=zstd:<level> threads Backend::from_name's validation
        // into the user-facing grammar (value keeps its colon: the kv
        // split is on the first '=').
        match CodecSpec::parse("fedgec:backend=zstd:19").unwrap() {
            CodecSpec::Fedgec { backend, .. } => assert_eq!(backend, Backend::Zstd(19)),
            other => panic!("{other:?}"),
        }
        match CodecSpec::parse("sz3:backend=none").unwrap() {
            CodecSpec::Sz3 { backend, .. } => assert_eq!(backend, Backend::None),
            other => panic!("{other:?}"),
        }
        // Canonical form keeps non-default backends and reparses.
        let s = CodecSpec::parse("fedgec:backend=zstd:19,ec=rans").unwrap();
        assert!(s.to_string().contains("backend=zstd:19"));
        assert_eq!(CodecSpec::parse(&s.to_string()).unwrap(), s);
        // Default backend is omitted; bad levels and names are rejected.
        assert!(!CodecSpec::parse("fedgec:backend=zstd").unwrap().to_string().contains("backend"));
        assert!(CodecSpec::parse("fedgec:backend=zstd:99").is_err());
        assert!(CodecSpec::parse("fedgec:backend=zstd:0").is_err());
        assert!(CodecSpec::parse("sz3:backend=bzip2").is_err());
        assert!(CodecSpec::parse("qsgd:backend=zstd").is_err(), "qsgd has no lossless stage");
    }

    #[test]
    fn entropy_coder_key_parses_and_roundtrips() {
        let s = CodecSpec::parse("fedgec:eb=rel1e-2,ec=rans").unwrap();
        match &s {
            CodecSpec::Fedgec { ec, .. } => assert_eq!(*ec, EntropyCoder::Rans),
            other => panic!("{other:?}"),
        }
        // Canonical form keeps the non-default coder and reparses.
        assert!(s.to_string().contains("ec=rans"));
        assert_eq!(CodecSpec::parse(&s.to_string()).unwrap(), s);
        assert_eq!(
            CodecSpec::parse("sz3:ec=raw").unwrap(),
            CodecSpec::Sz3 {
                eb: ErrorBound::Rel(1e-2),
                ec: EntropyCoder::Raw,
                backend: Backend::default()
            }
        );
        // The default coder is omitted from the canonical form.
        assert!(!CodecSpec::parse("fedgec:ec=huff").unwrap().to_string().contains("ec="));
        assert!(CodecSpec::parse("fedgec:ec=bogus").is_err());
        assert!(CodecSpec::parse("qsgd:ec=rans").is_err(), "qsgd has no entropy stage");
    }

    #[test]
    fn defaults_fill_missing_keys() {
        let d = SpecDefaults::with_rel_eb(3e-2);
        let s = CodecSpec::parse_with("fedgec", &d).unwrap();
        assert_eq!(
            s,
            CodecSpec::Fedgec {
                eb: ErrorBound::Rel(3e-2),
                beta: 0.9,
                tau: 0.5,
                full_batch: false,
                autotune: false,
                ec: EntropyCoder::Huffman,
                backend: Backend::default(),
                pred: MagnitudeSel::Ema,
                sign: SignSel::Auto
            }
        );
        // §5.3 pairing: eb 3e-2 ↔ 5 bits.
        assert_eq!(CodecSpec::parse_with("qsgd", &d).unwrap(), CodecSpec::Qsgd {
            bits: 5,
            seed: 0
        });
    }

    #[test]
    fn pred_and_sign_keys_parse_and_roundtrip() {
        let s = CodecSpec::parse("fedgec:pred=auto,sign=none").unwrap();
        match &s {
            CodecSpec::Fedgec { pred, sign, .. } => {
                assert_eq!(*pred, MagnitudeSel::Auto);
                assert_eq!(*sign, SignSel::None);
            }
            other => panic!("{other:?}"),
        }
        // Canonical form keeps non-default selectors and reparses.
        let text = s.to_string();
        assert!(text.contains("pred=auto") && text.contains("sign=none"), "{text}");
        assert_eq!(CodecSpec::parse(&text).unwrap(), s);
        // Defaults are omitted from the canonical form.
        let text = CodecSpec::parse("fedgec:pred=ema,sign=auto").unwrap().to_string();
        assert!(!text.contains("pred=") && !text.contains("sign="), "{text}");
        // `pred=ema:<beta>` doubles as a β setter.
        match CodecSpec::parse("fedgec:pred=ema:0.75").unwrap() {
            CodecSpec::Fedgec { pred, beta, .. } => {
                assert_eq!(pred, MagnitudeSel::Ema);
                assert!((beta - 0.75).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
        // Garbage values and misplaced keys are rejected.
        assert!(CodecSpec::parse("fedgec:pred=bogus").is_err());
        assert!(CodecSpec::parse("fedgec:pred=ema:xyz").is_err());
        assert!(CodecSpec::parse("fedgec:sign=bogus").is_err());
        assert!(CodecSpec::parse("sz3:pred=last").is_err(), "sz3 has no predictor stage");
        assert!(CodecSpec::parse("qsgd:sign=none").is_err());
        // Every selector name round-trips through the grammar.
        for pred in MagnitudeSel::ALL {
            for sign in SignSel::ALL {
                let text = format!("fedgec:eb=rel1e-2,pred={},sign={}", pred.name(), sign.name());
                let spec = CodecSpec::parse(&text).unwrap();
                assert_eq!(CodecSpec::parse(&spec.to_string()).unwrap(), spec, "{text}");
            }
        }
    }

    #[test]
    fn pred_ema_grammar_default_equals_struct_default() {
        // The EntropyCoder-style default-drift guard: a bare `pred=ema`
        // must resolve to exactly FedgecConfig::default().beta — all
        // three definitions share the DEFAULT_BETA constant.
        use crate::compress::predictor::magnitude::DEFAULT_BETA;
        assert_eq!(FedgecConfig::default().beta, DEFAULT_BETA);
        assert_eq!(SpecDefaults::default().beta, DEFAULT_BETA);
        match CodecSpec::parse("fedgec:pred=ema").unwrap() {
            CodecSpec::Fedgec { beta, pred, .. } => {
                assert_eq!(pred, MagnitudeSel::Ema);
                assert_eq!(beta, FedgecConfig::default().beta);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn display_parse_roundtrip() {
        let d = SpecDefaults::default();
        for spec in CodecSpec::registry_specs(&d) {
            let text = spec.to_string();
            let back = CodecSpec::parse(&text)
                .unwrap_or_else(|e| panic!("reparse '{text}': {e}"));
            assert_eq!(back, spec, "canonical form '{text}' must reparse");
        }
    }

    #[test]
    fn builds_every_registry_spec() {
        let d = SpecDefaults::default();
        for spec in CodecSpec::registry_specs(&d) {
            let codec = spec.build();
            assert!(!codec.name().is_empty());
        }
    }

    #[test]
    fn builds_engine_for_every_registry_spec() {
        // build_engine: statefulness matches the spec's stateless()
        // classification (EF's server side is pass-through, hence
        // stateless even though the spec as a whole is not).
        let d = SpecDefaults::default();
        for spec in CodecSpec::registry_specs(&d) {
            let engine = spec.build_engine();
            assert!(!engine.name().is_empty(), "{spec}");
            let expect_stateful = matches!(spec, CodecSpec::Fedgec { .. });
            assert_eq!(engine.stateful(), expect_stateful, "{spec}");
        }
        // The state-free fedgec mode (pred=zero + sign=none, no
        // autotune) builds a stateless engine — the bins-aggregation
        // eligible configuration (see compress::agg).
        let spec = CodecSpec::parse("fedgec:eb=abs1e-3,pred=zero,sign=none").unwrap();
        assert!(!spec.build_engine().stateful(), "{spec}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(CodecSpec::parse("").is_err());
        assert!(CodecSpec::parse("nope").is_err());
        assert!(CodecSpec::parse("fedgec:wat=1").is_err());
        assert!(CodecSpec::parse("qsgd:bits=40").is_err());
        assert!(CodecSpec::parse("qsgd:bits=5.7").is_err());
        assert!(CodecSpec::parse("qsgd:seed=-1").is_err());
        assert!(CodecSpec::parse("topk:k=0").is_err());
        assert!(CodecSpec::parse("ef(topk").is_err());
        assert!(CodecSpec::parse("raw:k=1").is_err());
        assert!(CodecSpec::parse("sz3:eb=xyz").is_err());
        // Degenerate bounds are rejected at parse time, naming the key,
        // instead of propagating a nonsense Δ into the quantizer.
        for bad in ["0", "-1e-2", "nan", "inf", "rel0", "abs-3", "relnan", "absinf"] {
            let spec = format!("fedgec:eb={bad}");
            let err = CodecSpec::parse(&spec).expect_err(&spec).to_string();
            assert!(err.contains("eb"), "error for {spec} names the key: {err}");
        }
        // Bare 'ef' needs the wrapper form.
        assert!(CodecSpec::parse("ef").is_err());
        assert!(CodecSpec::parse("ef:bits=5").is_err());
        // Legacy ef-* names take no params (the ef(...) form does).
        assert!(CodecSpec::parse("ef-qsgd:bits=8").is_err());
    }

    #[test]
    fn ef_rejects_stateful_inners() {
        // Error feedback's encoder-side scratch decode would desync a
        // cross-round-stateful inner — the parser refuses the composition.
        assert!(CodecSpec::parse("ef(fedgec)").is_err());
        assert!(CodecSpec::parse("ef(ef(topk:k=0.05))").is_err());
        // Stateless inners stay accepted.
        assert!(CodecSpec::parse("ef(topk:k=0.05)").is_ok());
        assert!(CodecSpec::parse("ef(qsgd:bits=5)").is_ok());
        assert!(CodecSpec::parse("ef(topk+eblc:k=0.05,eb=rel1e-2)").is_ok());
    }

    #[test]
    fn registry_covers_all_families() {
        let names: Vec<&str> = REGISTRY.iter().map(|f| f.family).collect();
        for spec in CodecSpec::registry_specs(&SpecDefaults::default()) {
            assert!(names.contains(&spec.family()), "{} missing", spec.family());
        }
        // Every registry example parses.
        for fam in REGISTRY {
            assert!(
                CodecSpec::parse(fam.example).is_ok(),
                "example '{}' must parse",
                fam.example
            );
        }
    }
}
