//! Downlink broadcast compression: encode-once / fan-out-many
//! compression of the **global-model delta** with drift-free reference
//! sync (the FedSZ observation that EBLC applies to both directions of
//! FL communication, and the spatio-temporal-correlation observation
//! that the global delta is smoother across rounds than any single
//! client gradient).
//!
//! # Model
//!
//! The server tracks a **reference model** — the lossy view every synced
//! client holds. Each round it compresses `Δ = θ_global − θ_ref` *once*
//! with an ordinary [`GradientCodec`] (one server-side cross-round
//! predictor state for the whole federation, not one per client), runs
//! the encoded frames back through its own mirror decoder, and applies
//! the **lossy reconstruction** to the reference. Every client applies
//! the same frames through the same decode path, so all views stay
//! bit-identical — and because the next round's delta is computed
//! against the *lossy* reference, quantization error is re-measured and
//! re-compressed every round instead of accumulating as drift (the same
//! closed loop that makes error-bounded quantizers stable).
//!
//! # Cold clients and stream resets
//!
//! The delta stream only decodes correctly for a client that has (a) the
//! current reference bytes and (b) the current downlink predictor state.
//! A cold client (first round, rejoin after a missed round, resync)
//! bootstraps (a) via a `FullSync` of the reference — but it cannot be
//! handed (b), so the round *after* any cold join the server resets its
//! encoder state and orders every synced client to do the same
//! (`reset = true` on the next delta broadcast). Both sides land on the
//! codec's deterministic round-1 path — the same philosophy as the
//! uplink `StateCheck`/`StateResync` handshake: divergence is resolved
//! by a deterministic cold start, never by silent drift.
//!
//! The server half ([`DownlinkCodec`]) plans the round; the client half
//! ([`DownlinkMirror`]) is also embedded in the server as its reference
//! tracker, so the invariant "server view == client view" holds by
//! construction: both run literally the same decode code.

use std::collections::HashSet;
use std::time::{Duration, Instant};

use super::frame::Frame;
use super::spec::CodecSpec;
use super::store::ClientId;
use super::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};

/// The client half (and the server's reference tracker): a downlink
/// codec mirror plus the reference model it keeps in sync.
pub struct DownlinkMirror {
    codec: Box<dyn GradientCodec>,
    metas: Vec<LayerMeta>,
    /// The tracked lossy model view; `None` until the first `FullSync`
    /// (or after a failed decode poisoned the view).
    reference: Option<Vec<Vec<f32>>>,
}

impl DownlinkMirror {
    pub fn new(spec: &CodecSpec, metas: Vec<LayerMeta>) -> Self {
        DownlinkMirror { codec: spec.build(), metas, reference: None }
    }

    pub fn metas(&self) -> &[LayerMeta] {
        &self.metas
    }

    /// The current model view (`None` before the first `FullSync`).
    pub fn params(&self) -> Option<&[Vec<f32>]> {
        self.reference.as_deref()
    }

    pub fn is_synced(&self) -> bool {
        self.reference.is_some()
    }

    /// Bootstrap (or re-bootstrap) from full reference bytes. Resets the
    /// delta-codec state: the stream restarts cold for this client, and
    /// the server orders everyone else to reset on its next broadcast.
    pub fn full_sync(&mut self, tensors: Vec<Vec<f32>>) -> crate::Result<()> {
        anyhow::ensure!(
            tensors.len() == self.metas.len()
                && tensors.iter().zip(&self.metas).all(|(t, m)| t.len() == m.numel),
            "full sync carries {} layers, model has {}",
            tensors.len(),
            self.metas.len()
        );
        self.codec.reset();
        self.reference = Some(tensors);
        Ok(())
    }

    /// Decode one round's delta frames and fold the reconstruction into
    /// the reference. A failed decode poisons the view (the client must
    /// re-bootstrap via `FullSync`) — a half-applied delta is divergence.
    pub fn apply_delta(&mut self, reset: bool, frames: &[Frame]) -> crate::Result<&[Vec<f32>]> {
        anyhow::ensure!(
            self.reference.is_some(),
            "delta broadcast before any full sync (cold client missed its bootstrap)"
        );
        if reset {
            self.codec.reset();
        }
        // Every failure past this point poisons the view: the server's
        // reference has already advanced, so a mirror that skipped this
        // delta (wrong shape, corrupt frame, mid-decode error) is one
        // round stale and must re-bootstrap, never claim sync.
        let mut decode = || -> crate::Result<()> {
            anyhow::ensure!(
                frames.len() == self.metas.len(),
                "delta has {} frames, model has {} layers",
                frames.len(),
                self.metas.len()
            );
            self.codec.begin(self.metas.len())?;
            let reference = self.reference.as_mut().expect("checked above");
            for (i, (frame, (meta, slot))) in
                frames.iter().zip(self.metas.iter().zip(reference.iter_mut())).enumerate()
            {
                anyhow::ensure!(
                    frame.index as usize == i,
                    "delta frame {} out of order ({})",
                    i,
                    frame.index
                );
                let (layer, _report) = self.codec.decode_frame(frame, meta)?;
                for (w, d) in slot.iter_mut().zip(&layer.data) {
                    *w += d;
                }
            }
            Ok(())
        };
        match decode() {
            Ok(()) => Ok(self.reference.as_deref().expect("checked above")),
            Err(e) => {
                self.reference = None;
                Err(e)
            }
        }
    }
}

/// One round's delta broadcast: encoded **once**, fanned out to every
/// warm participant.
pub struct DeltaBroadcast {
    /// Every synced client must cold-reset its decoder before decoding
    /// (the encoder restarted because a cold client joined last round).
    pub reset: bool,
    /// One frame per layer, in model order.
    pub frames: Vec<Frame>,
}

impl DeltaBroadcast {
    /// Total frame wire bytes (the shared payload each recipient pulls).
    pub fn wire_bytes(&self) -> usize {
        self.frames.iter().map(|f| f.wire_size()).sum()
    }
}

/// Accounting for one round of downlink compression.
#[derive(Debug, Clone, Copy, Default)]
pub struct DownlinkStats {
    /// Raw f32 model bytes (one copy, not multiplied by fan-out).
    pub raw_bytes: usize,
    /// Delta frame wire bytes (one copy; 0 on an all-cold round).
    pub delta_bytes: usize,
    /// Encode + reference-mirror decode time (paid once per round,
    /// amortized over the whole fan-out).
    pub encode_time: Duration,
    /// Whether the delta stream restarted cold this round.
    pub reset: bool,
}

/// The plan for one round's broadcast: who gets the shared delta frames
/// and who must bootstrap via `FullSync`.
pub struct RoundBroadcast {
    /// The shared encoded delta (`None` when no participant is synced —
    /// the stream restarts and everyone bootstraps).
    pub delta: Option<DeltaBroadcast>,
    /// Participants that must receive a `FullSync` of the post-round
    /// reference this round.
    pub cold: Vec<ClientId>,
    pub stats: DownlinkStats,
}

/// The server half: plans each round, encodes the delta once, and tracks
/// the reference through its own [`DownlinkMirror`] — the same decode
/// path every client runs.
pub struct DownlinkCodec {
    enc: Box<dyn GradientCodec>,
    mirror: DownlinkMirror,
    /// Clients holding the current reference + decoder state (receiving
    /// every broadcast since their last `FullSync`). A client that
    /// misses one delta round falls out and re-bootstraps.
    synced: HashSet<ClientId>,
    /// A cold client joined the warm stream last round: reset the
    /// encoder (and order every decoder to reset) on the next delta.
    pending_reset: bool,
}

impl DownlinkCodec {
    pub fn new(spec: &CodecSpec, metas: Vec<LayerMeta>) -> Self {
        DownlinkCodec {
            enc: spec.build(),
            mirror: DownlinkMirror::new(spec, metas),
            synced: HashSet::new(),
            pending_reset: false,
        }
    }

    /// The tracked reference model — bit-identical to every synced
    /// client's view (`None` before the first round).
    pub fn reference(&self) -> Option<&[Vec<f32>]> {
        self.mirror.params()
    }

    pub fn metas(&self) -> &[LayerMeta] {
        self.mirror.metas()
    }

    pub fn is_synced(&self, client: ClientId) -> bool {
        self.synced.contains(&client)
    }

    pub fn synced_count(&self) -> usize {
        self.synced.len()
    }

    /// Plan and encode one round's broadcast for `participants`: encode
    /// the delta once for the warm subset, advance the reference through
    /// the mirror decode, and list the cold subset that needs `FullSync`
    /// (built from [`Self::reference`] *after* this call, so full-sync
    /// recipients land on exactly the post-round view).
    pub fn encode_round(
        &mut self,
        params: &[Vec<f32>],
        participants: &[ClientId],
    ) -> crate::Result<RoundBroadcast> {
        anyhow::ensure!(
            params.len() == self.mirror.metas.len()
                && params.iter().zip(&self.mirror.metas).all(|(t, m)| t.len() == m.numel),
            "params shape does not match the downlink model ({} layers expected)",
            self.mirror.metas.len()
        );
        let cold: Vec<ClientId> =
            participants.iter().copied().filter(|id| !self.synced.contains(id)).collect();
        let warm_any = participants.iter().any(|id| self.synced.contains(id));
        let mut stats = DownlinkStats {
            raw_bytes: self.mirror.metas.iter().map(|m| m.numel * 4).sum(),
            ..Default::default()
        };
        let t0 = Instant::now();
        let delta = if warm_any {
            let reset = self.pending_reset;
            if reset {
                self.enc.reset();
                crate::telemetry::DOWNLINK_RESETS.inc();
            }
            // Δ = θ_global − θ_ref, shaped as a gradient so the kernel
            // sign predictor sees the model's layer structure.
            let grads = {
                let reference = self.mirror.params().expect("warm stream has a reference");
                ModelGrad {
                    layers: self
                        .mirror
                        .metas
                        .iter()
                        .zip(params.iter().zip(reference))
                        .map(|(meta, (p, r))| {
                            let data: Vec<f32> = p.iter().zip(r).map(|(p, r)| p - r).collect();
                            LayerGrad::new(meta.clone(), data)
                        })
                        .collect(),
                }
            };
            match self.enc.encode_model(&grads).and_then(|frames| {
                // Advance the reference through the SAME decode path
                // every client runs — the invariant by construction.
                self.mirror.apply_delta(reset, &frames)?;
                Ok(frames)
            }) {
                Ok(frames) => {
                    let delta = DeltaBroadcast { reset, frames };
                    stats.delta_bytes = delta.wire_bytes();
                    stats.reset = reset;
                    Some(delta)
                }
                Err(e) => {
                    // A failed encode/mirror-decode leaves no trustworthy
                    // stream: drop every subscription so the next round
                    // restarts from exact bytes.
                    self.enc.reset();
                    self.synced.clear();
                    self.pending_reset = false;
                    return Err(e);
                }
            }
        } else {
            // Nobody holds the reference: restart the stream exactly.
            // The reference becomes the *exact* current model and both
            // codec states go cold.
            self.enc.reset();
            crate::telemetry::DOWNLINK_RESETS.inc();
            self.mirror.full_sync(params.to_vec())?;
            stats.reset = true;
            None
        };
        stats.encode_time = t0.elapsed();
        crate::telemetry::DOWNLINK_CODEC_NS.add_duration(stats.encode_time);
        // A cold join into a warm stream forces next round's reset; an
        // all-cold restart already happened.
        self.pending_reset = warm_any && !cold.is_empty();
        self.synced = participants.iter().copied().collect();
        Ok(RoundBroadcast { delta, cold, stats })
    }
}

/// Measurement harness shared by the downlink bench panels and tests:
/// bootstrap `down` over `participants` (round 0 `FullSync`), then run
/// `rounds` delta rounds, calling `advance` to move the global model
/// before each encode. Returns total (delta frame bytes, encode time)
/// across the delta rounds.
pub fn measure_delta_stream(
    down: &mut DownlinkCodec,
    params: &mut [Vec<f32>],
    participants: &[ClientId],
    rounds: usize,
    mut advance: impl FnMut(&mut [Vec<f32>]),
) -> crate::Result<(usize, Duration)> {
    down.encode_round(params, participants)?; // bootstrap round
    let (mut delta_bytes, mut encode_time) = (0usize, Duration::ZERO);
    for _ in 0..rounds {
        advance(params);
        let bc = down.encode_round(params, participants)?;
        anyhow::ensure!(bc.cold.is_empty(), "persistent fan-out re-bootstrapped mid-stream");
        delta_bytes += bc.stats.delta_bytes;
        encode_time += bc.stats.encode_time;
    }
    Ok((delta_bytes, encode_time))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::spec::SpecDefaults;
    use crate::util::rng::Rng;

    fn spec(eb: f64) -> CodecSpec {
        CodecSpec::parse_with("fedgec", &SpecDefaults::with_rel_eb(eb)).unwrap()
    }

    fn metas() -> Vec<LayerMeta> {
        vec![
            LayerMeta::conv("conv", 32, 8, 3, 3), // 2304 > t_lossy
            LayerMeta::dense("fc", 64, 32),       // 2048 > t_lossy
            LayerMeta::other("bias", 16),         // lossless
        ]
    }

    fn init_params(rng: &mut Rng, metas: &[LayerMeta]) -> Vec<Vec<f32>> {
        metas
            .iter()
            .map(|m| (0..m.numel).map(|_| rng.normal_f32(0.0, 0.5)).collect())
            .collect()
    }

    fn step(params: &mut [Vec<f32>], rng: &mut Rng, scale: f32) {
        for t in params.iter_mut() {
            for v in t.iter_mut() {
                *v -= scale * rng.normal_f32(0.0, 0.02);
            }
        }
    }

    fn bits_eq(a: &[Vec<f32>], b: &[Vec<f32>]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.len() == y.len() && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
            })
    }

    #[test]
    fn first_round_bootstraps_everyone_exactly() {
        let metas = metas();
        let mut rng = Rng::new(1);
        let params = init_params(&mut rng, &metas);
        let mut down = DownlinkCodec::new(&spec(1e-3), metas.clone());
        let bc = down.encode_round(&params, &[0, 1, 2]).unwrap();
        assert!(bc.delta.is_none());
        assert_eq!(bc.cold, vec![0, 1, 2]);
        // All-cold restart: the reference is the exact model bytes.
        assert!(bits_eq(down.reference().unwrap(), &params));
        assert!(down.is_synced(1) && down.synced_count() == 3);
    }

    #[test]
    fn persistent_clients_stay_bit_identical() {
        let metas = metas();
        let mut rng = Rng::new(2);
        let mut params = init_params(&mut rng, &metas);
        let sp = spec(1e-3);
        let mut down = DownlinkCodec::new(&sp, metas.clone());
        let mut a = DownlinkMirror::new(&sp, metas.clone());
        let mut b = DownlinkMirror::new(&sp, metas.clone());
        for round in 0..6 {
            let bc = down.encode_round(&params, &[0, 1]).unwrap();
            for m in [&mut a, &mut b] {
                if bc.delta.is_none() || bc.cold.contains(&0) {
                    m.full_sync(down.reference().unwrap().to_vec()).unwrap();
                } else {
                    let d = bc.delta.as_ref().unwrap();
                    m.apply_delta(d.reset, &d.frames).unwrap();
                }
            }
            assert!(
                bits_eq(a.params().unwrap(), down.reference().unwrap()),
                "round {round}: client A diverged from the server reference"
            );
            assert!(bits_eq(a.params().unwrap(), b.params().unwrap()));
            if round > 0 {
                assert!(bc.delta.is_some(), "warm round {round} must stream a delta");
            }
            step(&mut params, &mut rng, 1.0);
        }
    }

    #[test]
    fn reference_error_stays_bounded_no_drift() {
        // Quantization error must not accumulate: after many rounds the
        // reference stays within one round's error bound of the true
        // model (the closed loop re-measures the residual every round).
        let metas = metas();
        let mut rng = Rng::new(3);
        let mut params = init_params(&mut rng, &metas);
        let mut down = DownlinkCodec::new(&spec(1e-3), metas.clone());
        down.encode_round(&params, &[0]).unwrap();
        for _ in 0..12 {
            step(&mut params, &mut rng, 1.0);
            down.encode_round(&params, &[0]).unwrap();
        }
        let reference = down.reference().unwrap();
        for (li, (p, r)) in params.iter().zip(reference).enumerate() {
            let (lo, hi) = crate::util::stats::finite_min_max(
                &p.iter().zip(r).map(|(a, b)| a - b).collect::<Vec<f32>>(),
            );
            // The *delta* being within the bound each round means the
            // views track: |θ − ref| equals the last round's residual,
            // which was quantized under its own bound. Just assert the
            // gap is small and finite, far from a 12-round accumulation.
            let worst = lo.abs().max(hi.abs());
            assert!(worst.is_finite() && worst < 0.05, "layer {li}: gap {worst}");
        }
    }

    #[test]
    fn cold_join_full_syncs_then_reset_realigns() {
        let metas = metas();
        let mut rng = Rng::new(4);
        let mut params = init_params(&mut rng, &metas);
        let sp = spec(1e-3);
        let mut down = DownlinkCodec::new(&sp, metas.clone());
        let mut a = DownlinkMirror::new(&sp, metas.clone());
        let mut c = DownlinkMirror::new(&sp, metas.clone());
        // Rounds 0..3: only client 0.
        for _ in 0..3 {
            let bc = down.encode_round(&params, &[0]).unwrap();
            match &bc.delta {
                None => a.full_sync(down.reference().unwrap().to_vec()).unwrap(),
                Some(d) => {
                    a.apply_delta(d.reset, &d.frames).unwrap();
                }
            }
            step(&mut params, &mut rng, 1.0);
        }
        // Round 3: client 2 cold-joins — it gets FullSync, A gets the
        // warm delta, and the stream schedules a reset.
        let bc = down.encode_round(&params, &[0, 2]).unwrap();
        assert_eq!(bc.cold, vec![2]);
        let d = bc.delta.as_ref().expect("warm client keeps the delta stream");
        assert!(!d.reset);
        a.apply_delta(d.reset, &d.frames).unwrap();
        c.full_sync(down.reference().unwrap().to_vec()).unwrap();
        assert!(bits_eq(c.params().unwrap(), a.params().unwrap()));
        // Round 4: the delta arrives with reset = true and BOTH mirrors
        // decode it to the same bytes.
        step(&mut params, &mut rng, 1.0);
        let bc = down.encode_round(&params, &[0, 2]).unwrap();
        assert!(bc.cold.is_empty());
        let d = bc.delta.as_ref().unwrap();
        assert!(d.reset, "the round after a cold join must reset the stream");
        a.apply_delta(d.reset, &d.frames).unwrap();
        c.apply_delta(d.reset, &d.frames).unwrap();
        assert!(bits_eq(a.params().unwrap(), down.reference().unwrap()));
        assert!(bits_eq(c.params().unwrap(), down.reference().unwrap()));
    }

    #[test]
    fn missed_round_drops_subscription() {
        let metas = metas();
        let mut rng = Rng::new(5);
        let mut params = init_params(&mut rng, &metas);
        let mut down = DownlinkCodec::new(&spec(1e-3), metas.clone());
        down.encode_round(&params, &[0, 1]).unwrap();
        step(&mut params, &mut rng, 1.0);
        // Client 1 misses this round…
        down.encode_round(&params, &[0]).unwrap();
        assert!(!down.is_synced(1));
        step(&mut params, &mut rng, 1.0);
        // …so its return is a cold join.
        let bc = down.encode_round(&params, &[0, 1]).unwrap();
        assert_eq!(bc.cold, vec![1]);
    }

    #[test]
    fn delta_before_full_sync_errors_and_bad_frames_poison() {
        let metas = metas();
        let sp = spec(1e-3);
        let mut m = DownlinkMirror::new(&sp, metas.clone());
        assert!(m.apply_delta(false, &[]).is_err());
        let mut rng = Rng::new(6);
        let params = init_params(&mut rng, &metas);
        m.full_sync(params.clone()).unwrap();
        assert!(m.is_synced());
        // Wrong frame count poisons the view → re-bootstrap required.
        assert!(m.apply_delta(false, &[]).is_err());
        assert!(!m.is_synced());
        m.full_sync(params).unwrap();
        // Shape mismatch on full sync is rejected up front.
        assert!(m.full_sync(vec![vec![0.0; 3]]).is_err());
    }

    #[test]
    fn raw_spec_downlink_is_exact() {
        // down=… accepts any registry spec; with `qsgd`/`topk` the delta
        // is not error-bounded but the mirror discipline still holds.
        // With `sz3` (stateless, error-bounded) everything works too.
        let metas = metas();
        let mut rng = Rng::new(7);
        let mut params = init_params(&mut rng, &metas);
        let sp = CodecSpec::parse_with("sz3", &SpecDefaults::with_rel_eb(1e-3)).unwrap();
        let mut down = DownlinkCodec::new(&sp, metas.clone());
        let mut a = DownlinkMirror::new(&sp, metas.clone());
        for _ in 0..4 {
            let bc = down.encode_round(&params, &[0]).unwrap();
            match &bc.delta {
                None => a.full_sync(down.reference().unwrap().to_vec()).unwrap(),
                Some(d) => {
                    a.apply_delta(d.reset, &d.frames).unwrap();
                }
            }
            assert!(bits_eq(a.params().unwrap(), down.reference().unwrap()));
            step(&mut params, &mut rng, 1.0);
        }
    }
}
