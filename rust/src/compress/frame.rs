//! Self-delimiting per-layer frames and the unified per-layer byte
//! accounting shared by every codec.
//!
//! A [`Frame`] is the unit of the session API ([`super::GradientCodec`]):
//! the encoder emits one frame per layer, the decoder consumes them in
//! order. Frames are self-delimiting on the wire (`u32` layer index +
//! `u32` payload length + payload), so they can be streamed one at a time
//! through [`crate::fl::protocol::Msg::UpdateFrame`] and the transport can
//! overlap layer `i`'s transmission with layer `i+1`'s compression.
//!
//! Every codec reports its per-layer raw/compressed/side-info byte split
//! through [`LayerReport`] — the single accounting type that replaced the
//! old `FedgecCodec::last_reports` / `Sz3Codec::last_ratios` duality — and
//! a whole-round session aggregates them into a [`CodecReport`].

use super::blob::{BlobReader, BlobWriter};
use super::predictor::sign::SignStats;
use super::CompressionStats;

/// Bytes of framing overhead per frame on the wire (index + length).
pub const FRAME_HEADER_BYTES: usize = 8;

/// One layer's encoded section: layer index + opaque codec payload, plus
/// the encoder-side accounting (not serialized).
#[derive(Debug, Clone, Default)]
pub struct Frame {
    /// Layer index within the model (drives per-layer codec state).
    pub index: u32,
    /// Codec-specific payload (already closed by the lossless backend).
    pub payload: Vec<u8>,
    /// Encoder-side accounting for this layer. Frames parsed back from
    /// wire bytes carry only the byte counts.
    pub report: LayerReport,
}

impl Frame {
    /// Build a frame, filling the report's `compressed_bytes` with the
    /// on-wire size (payload + framing header).
    pub fn new(index: usize, payload: Vec<u8>, mut report: LayerReport) -> Frame {
        report.compressed_bytes = payload.len() + FRAME_HEADER_BYTES;
        Frame { index: index as u32, payload, report }
    }

    /// On-wire size of this frame.
    pub fn wire_size(&self) -> usize {
        self.payload.len() + FRAME_HEADER_BYTES
    }

    /// Serialize to the self-delimiting wire form.
    pub fn to_wire(&self) -> Vec<u8> {
        let mut w = BlobWriter::new();
        self.write(&mut w);
        w.into_bytes()
    }

    /// Append the wire form to an open writer.
    pub fn write(&self, w: &mut BlobWriter) {
        w.put_u32(self.index);
        w.put_bytes(&self.payload);
    }

    /// Parse one frame from a reader positioned at a frame boundary.
    pub fn read(r: &mut BlobReader) -> crate::Result<Frame> {
        let index = r.get_u32()?;
        let payload = r.get_bytes()?.to_vec();
        let report = LayerReport {
            compressed_bytes: payload.len() + FRAME_HEADER_BYTES,
            ..Default::default()
        };
        Ok(Frame { index, payload, report })
    }

    /// Parse a frame from standalone wire bytes (e.g. an `UpdateFrame`
    /// message body).
    pub fn from_wire(buf: &[u8]) -> crate::Result<Frame> {
        let mut r = BlobReader::new(buf);
        let f = Self::read(&mut r)?;
        anyhow::ensure!(r.remaining() == 0, "trailing bytes after frame");
        Ok(f)
    }
}

/// Bundle an ordered frame sequence into one whole-model payload — the
/// blanket adapter's wire format (`u32` frame count + frames).
pub fn frames_to_payload(frames: &[Frame]) -> Vec<u8> {
    let mut w = BlobWriter::new();
    w.put_u32(frames.len() as u32);
    for f in frames {
        f.write(&mut w);
    }
    w.into_bytes()
}

/// Split a whole-model payload back into frames.
pub fn payload_to_frames(payload: &[u8]) -> crate::Result<Vec<Frame>> {
    let mut r = BlobReader::new(payload);
    let n = r.get_u32()? as usize;
    anyhow::ensure!(
        n.saturating_mul(FRAME_HEADER_BYTES) <= r.remaining(),
        "implausible frame count {n}"
    );
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(Frame::read(&mut r)?);
    }
    anyhow::ensure!(r.remaining() == 0, "trailing bytes after {n} frames");
    Ok(out)
}

/// Per-layer byte accounting, identical across all codec families.
#[derive(Debug, Clone, Default)]
pub struct LayerReport {
    pub name: String,
    /// Uncompressed layer bytes (`numel * 4`).
    pub raw_bytes: usize,
    /// On-wire bytes of this layer's frame (payload + framing header).
    pub compressed_bytes: usize,
    /// Side-information bytes: sign bitmaps, sparse indices, bucket
    /// norms, escape values — everything that is not the residual stream.
    pub side_info_bytes: usize,
    /// Entropy-coded residual stream bytes (0 for non-entropy codecs).
    pub entropy_bytes: usize,
    /// Stage-3 coder that produced `entropy_bytes` (`"huff"`/`"rans"`/
    /// `"raw"`; empty for non-entropy codecs and lossless layers).
    pub entropy_coder: String,
    /// Magnitude predictor that produced this layer's frame (`"ema"`/
    /// `"last"`/`"zero"` — the frame's wire tag, set identically by the
    /// fedgec encode and decode paths; empty for other codecs and for
    /// lossless layers).
    pub pred_tag: String,
    /// `pred=auto` race log: candidate name → exact residual-stage cost
    /// in bytes (entropy stream + escapes + predictor header). Encoder
    /// side only; empty unless the race ran.
    pub pred_race: Vec<(String, usize)>,
    /// Whether the lossy pipeline ran (small layers are stored lossless).
    pub lossy: bool,
    /// Escaped (stored-exact) element count for EBLC codecs.
    pub escape_count: usize,
    /// Sign-predictor statistics (FedGEC only; zeros elsewhere).
    pub sign_stats: SignStats,
    /// Server aggregation route this layer decoded onto (`"binsum"` /
    /// `"exact"`; empty outside the `decode_*_to_bins` path). See
    /// [`crate::compress::agg`].
    pub agg_route: String,
}

impl LayerReport {
    /// Compression ratio of this layer alone.
    pub fn ratio(&self) -> f64 {
        CompressionStats { raw_bytes: self.raw_bytes, compressed_bytes: self.compressed_bytes }
            .ratio()
    }
}

/// A whole round's unified report: one [`LayerReport`] per layer, in
/// model order — what every codec returns through the session API.
#[derive(Debug, Clone, Default)]
pub struct CodecReport {
    /// Codec name (as reported by `GradientCodec::name`).
    pub codec: String,
    pub layers: Vec<LayerReport>,
}

impl CodecReport {
    pub fn new(codec: &str) -> Self {
        CodecReport { codec: codec.to_string(), layers: Vec::new() }
    }

    /// Collect the encoder-side reports carried by a frame sequence.
    pub fn from_frames(codec: &str, frames: &[Frame]) -> Self {
        CodecReport {
            codec: codec.to_string(),
            layers: frames.iter().map(|f| f.report.clone()).collect(),
        }
    }

    pub fn push(&mut self, r: LayerReport) {
        self.layers.push(r);
    }

    /// Aggregate byte totals across layers.
    pub fn totals(&self) -> CompressionStats {
        let mut s = CompressionStats::default();
        for l in &self.layers {
            s.add(l.raw_bytes, l.compressed_bytes);
        }
        s
    }

    pub fn total_raw(&self) -> usize {
        self.totals().raw_bytes
    }

    pub fn total_compressed(&self) -> usize {
        self.totals().compressed_bytes
    }

    /// Whole-round compression ratio.
    pub fn ratio(&self) -> f64 {
        self.totals().ratio()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_wire_roundtrip() {
        let f = Frame::new(3, vec![1, 2, 3, 4, 5], LayerReport::default());
        assert_eq!(f.wire_size(), 5 + FRAME_HEADER_BYTES);
        let back = Frame::from_wire(&f.to_wire()).unwrap();
        assert_eq!(back.index, 3);
        assert_eq!(back.payload, vec![1, 2, 3, 4, 5]);
        assert_eq!(back.report.compressed_bytes, f.wire_size());
    }

    #[test]
    fn payload_roundtrip_preserves_order() {
        let frames: Vec<Frame> = (0..4)
            .map(|i| Frame::new(i, vec![i as u8; i + 1], LayerReport::default()))
            .collect();
        let payload = frames_to_payload(&frames);
        let back = payload_to_frames(&payload).unwrap();
        assert_eq!(back.len(), 4);
        for (i, f) in back.iter().enumerate() {
            assert_eq!(f.index as usize, i);
            assert_eq!(f.payload.len(), i + 1);
        }
    }

    #[test]
    fn corrupt_payload_errors() {
        assert!(payload_to_frames(&[1, 2]).is_err());
        let frames = vec![Frame::new(0, vec![9; 10], LayerReport::default())];
        let mut payload = frames_to_payload(&frames);
        payload.truncate(payload.len() - 3);
        assert!(payload_to_frames(&payload).is_err());
        // Trailing garbage is rejected too.
        let mut payload = frames_to_payload(&frames);
        payload.push(0xAB);
        assert!(payload_to_frames(&payload).is_err());
    }

    #[test]
    fn report_totals_and_ratio() {
        let mut rep = CodecReport::new("demo");
        rep.push(LayerReport { raw_bytes: 100, compressed_bytes: 10, ..Default::default() });
        rep.push(LayerReport { raw_bytes: 100, compressed_bytes: 10, ..Default::default() });
        assert_eq!(rep.total_raw(), 200);
        assert_eq!(rep.total_compressed(), 20);
        assert!((rep.ratio() - 10.0).abs() < 1e-12);
        // Empty reports read as "nothing happened", ratio 1.
        assert_eq!(CodecReport::new("empty").ratio(), 1.0);
    }
}
