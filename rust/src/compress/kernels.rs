//! Kernel-path selection for the compressor's hot loops (§Perf,
//! DESIGN.md §12).
//!
//! Every hot loop in the predict→quantize→entropy path ships as a
//! **twin pair**: a `*_scalar` reference kernel (bounds-checked, the
//! seed's shape) and a `*_fast` kernel (fixed-width chunks, `unsafe`
//! unchecked indexing justified by loop-bound invariants, batched bit
//! I/O). The two are bit-identical by construction — same per-element
//! f32 operation order, only the iteration bookkeeping differs — and
//! the registry-wide property tests assert byte-identical output
//! streams between the paths.
//!
//! Selection is two-level:
//!
//! * the `scalar-kernels` cargo feature hard-forces the scalar twins
//!   (the A/B build CI benchmarks against, and the baseline for the
//!   Miri job's unsafe-free control);
//! * [`force_scalar`] flips the path at runtime inside one build, so
//!   equivalence tests and the `perf_throughput` scalar-vs-fast panels
//!   run both twins from a single binary.
//!
//! The flag is read **once per kernel call**, outside the inner loops
//! (one relaxed atomic load per layer-stage invocation — never per
//! element), so the dispatch itself costs nothing measurable.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Runtime override: when set, public kernel entry points dispatch to
/// the scalar twins even in a default (fast) build.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Serializes [`with_scalar_kernels`] scopes so concurrently running
/// tests cannot observe each other's path flips.
static SCOPE: Mutex<()> = Mutex::new(());

/// Whether kernel entry points should take the scalar path right now.
#[inline]
pub fn scalar_kernels() -> bool {
    cfg!(feature = "scalar-kernels") || FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Flip the runtime scalar override. Prefer [`with_scalar_kernels`],
/// which scopes and serializes the flip; this raw setter exists for
/// benches that interleave timed sections on one thread.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// Run `f` with the scalar twins selected, restoring the fast path
/// after (also on panic). Scopes are mutex-serialized: concurrent
/// callers queue rather than racing the global flag.
pub fn with_scalar_kernels<R>(f: impl FnOnce() -> R) -> R {
    let _guard = SCOPE.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            force_scalar(false);
        }
    }
    let _restore = Restore;
    force_scalar(true);
    f()
}

/// Fixed chunk width of the elementwise fast kernels (fused predict +
/// quantize, dequantize). 16 f32 lanes = one AVX-512 vector or four
/// 128-bit vectors — wide enough for the autovectorizer, small enough
/// that per-chunk escape fallbacks stay cheap.
pub const CHUNK: usize = 16;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_override_restores() {
        with_scalar_kernels(|| assert!(scalar_kernels()));
        // Scopes restore the flag before releasing the mutex, so while
        // holding it no other test's scope can be mid-flight (asserting
        // without the lock would race parallel test threads).
        let _guard = SCOPE.lock().unwrap_or_else(|e| e.into_inner());
        #[cfg(not(feature = "scalar-kernels"))]
        assert!(!scalar_kernels());
    }

    #[test]
    fn feature_build_always_scalar() {
        #[cfg(feature = "scalar-kernels")]
        assert!(scalar_kernels());
    }

    #[test]
    fn restore_runs_on_panic() {
        let r = std::panic::catch_unwind(|| with_scalar_kernels(|| panic!("boom")));
        assert!(r.is_err());
        let _guard = SCOPE.lock().unwrap_or_else(|e| e.into_inner());
        #[cfg(not(feature = "scalar-kernels"))]
        assert!(!scalar_kernels());
    }
}
