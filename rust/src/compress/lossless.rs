//! Stage 4: the lossless backend applied to the entropy-coded stream.
//! The paper uses "Zstd or Blosc"; we default to real Zstd (vendored
//! `zstd` crate), with Deflate (`flate2`) and the from-scratch LZ77
//! ([`super::lz`]) available for the backend ablation, plus `None` for
//! measuring the entropy stage in isolation.

use std::io::{Read, Write};

/// Which general-purpose compressor closes the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Zstandard at the given level (paper default; level 3 ≈ zstd CLI default).
    Zstd(i32),
    /// DEFLATE via flate2.
    Deflate,
    /// The from-scratch LZ77 in `compress::lz`.
    OwnLz,
    /// Identity (ablation / debugging).
    None,
}

impl Default for Backend {
    fn default() -> Self {
        Backend::Zstd(3)
    }
}

impl Backend {
    pub fn from_name(s: &str) -> Option<Backend> {
        Some(match s {
            "zstd" => Backend::Zstd(3),
            "deflate" => Backend::Deflate,
            "ownlz" | "lz" => Backend::OwnLz,
            "none" => Backend::None,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Zstd(_) => "zstd",
            Backend::Deflate => "deflate",
            Backend::OwnLz => "ownlz",
            Backend::None => "none",
        }
    }

    fn tag(&self) -> u8 {
        match self {
            Backend::Zstd(_) => 1,
            Backend::Deflate => 2,
            Backend::OwnLz => 3,
            Backend::None => 0,
        }
    }

    /// Compress `data`, prefixing a 1-byte backend tag so decompression is
    /// self-describing.
    pub fn compress(&self, data: &[u8]) -> anyhow::Result<Vec<u8>> {
        let mut out = vec![self.tag()];
        match self {
            Backend::Zstd(level) => {
                let body = zstd::stream::encode_all(data, *level)?;
                out.extend_from_slice(&body);
            }
            Backend::Deflate => {
                let mut enc =
                    flate2::write::DeflateEncoder::new(&mut out, flate2::Compression::default());
                enc.write_all(data)?;
                enc.finish()?;
            }
            Backend::OwnLz => {
                out.extend_from_slice(&super::lz::compress(data));
            }
            Backend::None => {
                out.extend_from_slice(data);
            }
        }
        Ok(out)
    }
}

/// Decompress a tagged stream produced by [`Backend::compress`].
pub fn decompress(data: &[u8]) -> anyhow::Result<Vec<u8>> {
    let (tag, body) = data.split_first().ok_or_else(|| anyhow::anyhow!("empty lossless blob"))?;
    match tag {
        0 => Ok(body.to_vec()),
        1 => Ok(zstd::stream::decode_all(body)?),
        2 => {
            let mut dec = flate2::read::DeflateDecoder::new(body);
            let mut out = Vec::new();
            dec.read_to_end(&mut out)?;
            Ok(out)
        }
        3 => super::lz::decompress(body),
        t => anyhow::bail!("unknown lossless backend tag {t}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        let mut v = Vec::new();
        for i in 0..10_000u32 {
            v.extend_from_slice(&(i % 100).to_le_bytes());
        }
        v
    }

    #[test]
    fn all_backends_roundtrip() {
        let data = sample();
        for b in [Backend::Zstd(3), Backend::Deflate, Backend::OwnLz, Backend::None] {
            let c = b.compress(&data).unwrap();
            let d = decompress(&c).unwrap();
            assert_eq!(d, data, "backend {}", b.name());
        }
    }

    #[test]
    fn compressing_backends_shrink_redundant_data() {
        let data = sample();
        for b in [Backend::Zstd(3), Backend::Deflate, Backend::OwnLz] {
            let c = b.compress(&data).unwrap();
            assert!(c.len() < data.len() / 2, "backend {} got {}", b.name(), c.len());
        }
    }

    #[test]
    fn empty_roundtrip() {
        for b in [Backend::Zstd(3), Backend::Deflate, Backend::OwnLz, Backend::None] {
            let c = b.compress(&[]).unwrap();
            assert_eq!(decompress(&c).unwrap(), Vec::<u8>::new());
        }
    }

    #[test]
    fn name_parse_roundtrip() {
        for b in [Backend::Zstd(3), Backend::Deflate, Backend::OwnLz, Backend::None] {
            assert_eq!(Backend::from_name(b.name()).unwrap().name(), b.name());
        }
    }

    #[test]
    fn unknown_tag_errors() {
        assert!(decompress(&[9, 1, 2, 3]).is_err());
        assert!(decompress(&[]).is_err());
    }
}
