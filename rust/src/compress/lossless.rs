//! Stage 4: the lossless backend applied to the entropy-coded stream.
//! The paper uses "Zstd or Blosc"; we default to real Zstd (vendored
//! `zstd` crate), with Deflate (`flate2`) and the from-scratch LZ77
//! ([`super::lz`]) available for the backend ablation, plus `None` for
//! measuring the entropy stage in isolation.

use std::io::{Read, Write};

/// Which general-purpose compressor closes the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Zstandard at the given level (paper default; level 3 ≈ zstd CLI default).
    Zstd(i32),
    /// DEFLATE via flate2.
    Deflate,
    /// The from-scratch LZ77 in `compress::lz`.
    OwnLz,
    /// Identity (ablation / debugging).
    None,
}

impl Default for Backend {
    fn default() -> Self {
        Backend::Zstd(3)
    }
}

impl Backend {
    /// Zstd levels we accept (`zstd::compression_level_range()` without
    /// the 0 = "library default" alias, which would hide typos).
    pub const ZSTD_LEVELS: std::ops::RangeInclusive<i32> = 1..=22;

    /// Parse a backend name: `zstd` (level 3), `zstd:<level>`, `deflate`,
    /// `ownlz`/`lz`, `none`. Malformed names and out-of-range zstd levels
    /// are parse errors, never silent defaults.
    pub fn from_name(s: &str) -> anyhow::Result<Backend> {
        if let Some(rest) = s.strip_prefix("zstd:") {
            let level: i32 = rest
                .parse()
                .map_err(|_| anyhow::anyhow!("bad zstd level '{rest}' (want an integer)"))?;
            anyhow::ensure!(
                Self::ZSTD_LEVELS.contains(&level),
                "zstd level {level} outside {:?}",
                Self::ZSTD_LEVELS
            );
            return Ok(Backend::Zstd(level));
        }
        Ok(match s {
            "zstd" => Backend::Zstd(3),
            "deflate" => Backend::Deflate,
            "ownlz" | "lz" => Backend::OwnLz,
            "none" => Backend::None,
            _ => anyhow::bail!("unknown lossless backend '{s}' (zstd[:level]|deflate|ownlz|none)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Zstd(_) => "zstd",
            Backend::Deflate => "deflate",
            Backend::OwnLz => "ownlz",
            Backend::None => "none",
        }
    }

    /// Canonical spec-grammar form, the inverse of [`Self::from_name`]:
    /// keeps the zstd level when it differs from the default 3.
    pub fn spec_name(&self) -> String {
        match self {
            Backend::Zstd(3) => "zstd".to_string(),
            Backend::Zstd(level) => format!("zstd:{level}"),
            b => b.name().to_string(),
        }
    }

    fn tag(&self) -> u8 {
        match self {
            Backend::Zstd(_) => 1,
            Backend::Deflate => 2,
            Backend::OwnLz => 3,
            Backend::None => 0,
        }
    }

    /// Compress `data`, prefixing a 1-byte backend tag so decompression is
    /// self-describing.
    pub fn compress(&self, data: &[u8]) -> anyhow::Result<Vec<u8>> {
        let mut out = vec![self.tag()];
        match self {
            Backend::Zstd(level) => {
                let body = zstd::stream::encode_all(data, *level)?;
                out.extend_from_slice(&body);
            }
            Backend::Deflate => {
                let mut enc =
                    flate2::write::DeflateEncoder::new(&mut out, flate2::Compression::default());
                enc.write_all(data)?;
                enc.finish()?;
            }
            Backend::OwnLz => {
                out.extend_from_slice(&super::lz::compress(data));
            }
            Backend::None => {
                out.extend_from_slice(data);
            }
        }
        Ok(out)
    }
}

/// Decompress a tagged stream produced by [`Backend::compress`].
pub fn decompress(data: &[u8]) -> anyhow::Result<Vec<u8>> {
    let (tag, body) = data.split_first().ok_or_else(|| anyhow::anyhow!("empty lossless blob"))?;
    match tag {
        0 => Ok(body.to_vec()),
        1 => Ok(zstd::stream::decode_all(body)?),
        2 => {
            let mut dec = flate2::read::DeflateDecoder::new(body);
            let mut out = Vec::new();
            dec.read_to_end(&mut out)?;
            Ok(out)
        }
        3 => super::lz::decompress(body),
        t => anyhow::bail!("unknown lossless backend tag {t}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        let mut v = Vec::new();
        for i in 0..10_000u32 {
            v.extend_from_slice(&(i % 100).to_le_bytes());
        }
        v
    }

    #[test]
    fn all_backends_roundtrip() {
        let data = sample();
        for b in [Backend::Zstd(3), Backend::Deflate, Backend::OwnLz, Backend::None] {
            let c = b.compress(&data).unwrap();
            let d = decompress(&c).unwrap();
            assert_eq!(d, data, "backend {}", b.name());
        }
    }

    #[test]
    fn compressing_backends_shrink_redundant_data() {
        let data = sample();
        for b in [Backend::Zstd(3), Backend::Deflate, Backend::OwnLz] {
            let c = b.compress(&data).unwrap();
            assert!(c.len() < data.len() / 2, "backend {} got {}", b.name(), c.len());
        }
    }

    #[test]
    fn empty_roundtrip() {
        for b in [Backend::Zstd(3), Backend::Deflate, Backend::OwnLz, Backend::None] {
            let c = b.compress(&[]).unwrap();
            assert_eq!(decompress(&c).unwrap(), Vec::<u8>::new());
        }
    }

    #[test]
    fn name_parse_roundtrip() {
        for b in [Backend::Zstd(3), Backend::Deflate, Backend::OwnLz, Backend::None] {
            assert_eq!(Backend::from_name(b.name()).unwrap().name(), b.name());
        }
    }

    #[test]
    fn zstd_level_parses_and_validates() {
        assert_eq!(Backend::from_name("zstd").unwrap(), Backend::Zstd(3));
        assert_eq!(Backend::from_name("zstd:1").unwrap(), Backend::Zstd(1));
        assert_eq!(Backend::from_name("zstd:19").unwrap(), Backend::Zstd(19));
        assert_eq!(Backend::from_name("zstd:22").unwrap(), Backend::Zstd(22));
        // Out-of-range and malformed levels are errors, not defaults.
        assert!(Backend::from_name("zstd:0").is_err());
        assert!(Backend::from_name("zstd:23").is_err());
        assert!(Backend::from_name("zstd:-5").is_err());
        assert!(Backend::from_name("zstd:fast").is_err());
        assert!(Backend::from_name("zstd:").is_err());
        assert!(Backend::from_name("bzip2").is_err());
        // A parsed non-default level really compresses/decompresses.
        let data = sample();
        let c = Backend::from_name("zstd:7").unwrap().compress(&data).unwrap();
        assert_eq!(decompress(&c).unwrap(), data);
        // spec_name is the parse inverse, level included.
        for s in ["zstd", "zstd:7", "zstd:22", "deflate", "ownlz", "none"] {
            let b = Backend::from_name(s).unwrap();
            assert_eq!(b.spec_name(), s);
            assert_eq!(Backend::from_name(&b.spec_name()).unwrap(), b);
        }
    }

    #[test]
    fn unknown_tag_errors() {
        assert!(decompress(&[9, 1, 2, 3]).is_err());
        assert!(decompress(&[]).is_err());
    }
}
