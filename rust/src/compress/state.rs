//! Per-layer cross-round predictor state, mirrored on client and server.
//!
//! Both sides update their state **only** from data derivable from the
//! payload (reconstructed gradients), so after every round the two copies
//! are bit-identical — asserted by the `state_sync` integration test.
//!
//! Ownership: the *client* keeps its state inside its codec object (one
//! client, one state). The *server* no longer mirrors one codec per
//! client; it runs a stateless [`crate::compress::engine::CodecEngine`]
//! and fetches each client's [`ClientState`] per round from a
//! [`crate::compress::store::StateStore`] keyed by stable client id.
//! Every state carries a [`StateEpoch`] `(rounds, fingerprint)` so the
//! two sides can detect divergence (eviction, dropout, cold rejoin) and
//! deterministically reset to the codec's round-1 path instead of
//! silently drifting apart.

/// State for one layer.
#[derive(Debug, Clone, Default)]
pub struct LayerState {
    /// Magnitude-predictor selector tag that produced this state
    /// ([`crate::compress::predictor::magnitude::MagnitudeSel::state_tag`]).
    /// Folded into the fingerprint and the `FGS3` spill record, so state
    /// written under one predictor configuration can never be mistaken
    /// for another's across evict→reload or the `StateCheck` handshake.
    /// Stays 0 (the `ema` default) on layers that never ran the lossy
    /// pipeline; deliberately **not** part of [`Self::is_empty`] — it is
    /// config-derived, and an empty layer is cold regardless of config.
    pub pred: u8,
    /// Canonical bits of the [`crate::compress::quant::ErrorBound`] the
    /// last lossy round ran under ([`ErrorBound::state_bits`]). Folded
    /// into the fingerprint and the `FGS3` spill record exactly like
    /// `pred`: state shaped by one error bound must never be mistaken
    /// for another's after an `ebc=` controller changes the bound
    /// mid-run. 0 = unset (never lossy-coded); like `pred`, excluded
    /// from [`Self::is_empty`].
    pub eb: u32,
    /// EMA memory `m` of Alg. 1 (empty until round 2).
    pub memory: Vec<f32>,
    /// Previous reconstructed gradient `g̃^(t-1)`.
    pub prev_recon: Option<Vec<f32>>,
    /// Previous sign tensor (sign of `g̃^(t-1)`), used by full-batch mode.
    pub prev_sign: Option<Vec<f32>>,
    /// Previous absolute reconstruction `|g̃^(t-1)|` (cached for Alg. 1).
    pub prev_abs: Option<Vec<f32>>,
    /// `|g̃^(t-2)|` — feeds the deterministic β auto-tuner (both sides
    /// hold identical copies; see `compress::autotune`).
    pub prev_prev_abs: Option<Vec<f32>>,
}

impl LayerState {
    /// Absorb this round's reconstruction, reusing existing buffer
    /// capacity (this runs once per layer per round on both sides).
    pub fn absorb(&mut self, recon: &[f32]) {
        fn refill(slot: &mut Option<Vec<f32>>, src: impl Iterator<Item = f32>, n: usize) {
            let buf = slot.get_or_insert_with(|| Vec::with_capacity(n));
            buf.clear();
            buf.extend(src);
        }
        let n = recon.len();
        // Shift the |g̃| history (swap keeps the old buffer's capacity).
        std::mem::swap(&mut self.prev_prev_abs, &mut self.prev_abs);
        refill(&mut self.prev_sign, recon.iter().map(|&x| {
            if x > 0.0 {
                1.0
            } else if x < 0.0 {
                -1.0
            } else {
                0.0
            }
        }), n);
        refill(&mut self.prev_abs, recon.iter().map(|x| x.abs()), n);
        refill(&mut self.prev_recon, recon.iter().copied(), n);
    }

    pub fn reset(&mut self) {
        self.pred = 0;
        self.eb = 0;
        self.memory.clear();
        self.prev_recon = None;
        self.prev_sign = None;
        self.prev_abs = None;
        self.prev_prev_abs = None;
    }

    /// A layer that has never absorbed a round (or was reset). Empty
    /// layers contribute nothing to the state fingerprint, so a reset
    /// state and a freshly allocated one are indistinguishable — the
    /// property the cold-start resync check relies on.
    pub fn is_empty(&self) -> bool {
        self.memory.is_empty()
            && self.prev_recon.is_none()
            && self.prev_sign.is_none()
            && self.prev_abs.is_none()
            && self.prev_prev_abs.is_none()
    }

    /// Resident bytes of this layer's buffers (store budget accounting).
    pub fn byte_size(&self) -> usize {
        let opt = |v: &Option<Vec<f32>>| v.as_ref().map_or(0, |v| v.len() * 4);
        self.memory.len() * 4
            + opt(&self.prev_recon)
            + opt(&self.prev_sign)
            + opt(&self.prev_abs)
            + opt(&self.prev_prev_abs)
    }

    /// Recompute the views that are pure functions of `prev_recon`
    /// (`prev_sign`, `prev_abs`) — exactly what [`Self::absorb`] fills.
    /// The spill-to-disk store elides them from the serialized record and
    /// calls this on load; the recomputation is bit-exact because `|x|`
    /// and `sign(x)` are deterministic f32 → f32 maps.
    pub fn rebuild_derived(&mut self) {
        match &self.prev_recon {
            Some(r) => {
                self.prev_sign = Some(
                    r.iter()
                        .map(|&x| {
                            if x > 0.0 {
                                1.0
                            } else if x < 0.0 {
                                -1.0
                            } else {
                                0.0
                            }
                        })
                        .collect(),
                );
                self.prev_abs = Some(r.iter().map(|x| x.abs()).collect());
            }
            None => {
                self.prev_sign = None;
                self.prev_abs = None;
            }
        }
    }

    /// Digest of the state for sync checks (cheap structural
    /// fingerprint). Covers every mirrored buffer that influences future
    /// decodes: the predictor selector tag (state shaped by one
    /// predictor must never check as another's), the error-bound bits
    /// (same rule under `ebc=` controllers), `memory`, `prev_recon`,
    /// and `prev_prev_abs` (the β auto-tuner input — mirrored, and *not*
    /// derivable from the current `prev_recon`). `prev_sign`/`prev_abs`
    /// are pure functions of `prev_recon`, so hashing them would add
    /// cost without coverage. Domain tags separate the sections so
    /// content cannot alias across field boundaries.
    pub fn fingerprint(&self) -> u64 {
        fn mix(h: u64, bits: u32) -> u64 {
            (h ^ bits as u64).wrapping_mul(0x100000001b3)
        }
        let mut h = 0xcbf29ce484222325u64;
        h = mix(h, 0x5EED_0100 | self.pred as u32);
        h = mix(h, 0x5EED_0200);
        h = mix(h, self.eb);
        for v in &self.memory {
            h = mix(h, v.to_bits());
        }
        if let Some(r) = &self.prev_recon {
            h = mix(h, 0x5EED_0001);
            for v in r {
                h = mix(h, v.to_bits());
            }
        }
        if let Some(p) = &self.prev_prev_abs {
            h = mix(h, 0x5EED_0002);
            for v in p {
                h = mix(h, v.to_bits());
            }
        }
        h
    }
}

/// All layers of one peer's codec state.
#[derive(Debug, Clone, Default)]
pub struct CodecState {
    pub layers: Vec<LayerState>,
}

impl CodecState {
    /// Ensure `n` layer slots exist.
    pub fn ensure(&mut self, n: usize) {
        while self.layers.len() < n {
            self.layers.push(LayerState::default());
        }
    }
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.reset();
        }
    }

    /// Resident bytes across all layers (store budget accounting).
    pub fn byte_size(&self) -> usize {
        self.layers.iter().map(|l| l.byte_size()).sum()
    }

    /// Content-based digest: empty layer slots are skipped, so a state
    /// that was merely `ensure`d (or reset) fingerprints identically to
    /// [`CodecState::default`] — i.e. "cold" is a fingerprint, not a
    /// structural accident. Non-empty layers are mixed with their index
    /// so swapped layer contents still diverge.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for (idx, l) in self.layers.iter().enumerate() {
            if l.is_empty() {
                continue;
            }
            h = h.wrapping_mul(31).wrapping_add(idx as u64 ^ l.fingerprint());
        }
        h
    }
}

/// The epoch of one client's mirrored predictor state: how many rounds
/// it has absorbed, and the content fingerprint after the last absorb.
/// Client and server each track their own copy; the
/// `StateCheck`/`StateResync` handshake compares them and resets both
/// sides to cold start on any mismatch (paper §4.1's synchronization
/// invariant, restated as: the two mirrors agree iff their epochs agree).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StateEpoch {
    /// Number of rounds this state has absorbed (0 = cold).
    pub rounds: u32,
    /// [`CodecState::fingerprint`] after the last absorb.
    pub fingerprint: u64,
}

impl StateEpoch {
    /// The epoch of a state that has never absorbed a round.
    pub fn cold() -> StateEpoch {
        StateEpoch { rounds: 0, fingerprint: CodecState::default().fingerprint() }
    }

    pub fn is_cold(&self) -> bool {
        *self == StateEpoch::cold()
    }

    /// Record one absorbed round.
    pub fn advance(&mut self, state_fingerprint: u64) {
        self.rounds += 1;
        self.fingerprint = state_fingerprint;
    }
}

impl Default for StateEpoch {
    fn default() -> Self {
        StateEpoch::cold()
    }
}

/// One client's externally owned mirror state: the codec state plus its
/// epoch — the unit a [`crate::compress::store::StateStore`] checks in
/// and out per round.
#[derive(Debug, Clone, Default)]
pub struct ClientState {
    pub codec: CodecState,
    pub epoch: StateEpoch,
}

impl ClientState {
    /// A cold-start state (the codec's round-1 path).
    pub fn cold() -> ClientState {
        ClientState::default()
    }

    /// Resident bytes (store budget accounting; the epoch is free).
    pub fn byte_size(&self) -> usize {
        self.codec.byte_size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_populates_all_views() {
        let mut st = LayerState::default();
        st.absorb(&[1.5, -2.0, 0.0]);
        assert_eq!(st.prev_recon.as_deref(), Some(&[1.5, -2.0, 0.0][..]));
        assert_eq!(st.prev_sign.as_deref(), Some(&[1.0, -1.0, 0.0][..]));
        assert_eq!(st.prev_abs.as_deref(), Some(&[1.5, 2.0, 0.0][..]));
    }

    #[test]
    fn fingerprint_distinguishes_states() {
        let mut a = LayerState::default();
        let mut b = LayerState::default();
        a.absorb(&[1.0, 2.0]);
        b.absorb(&[1.0, 2.0]);
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.absorb(&[1.0, 2.5]);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn fingerprint_covers_prev_prev_abs() {
        // prev_prev_abs feeds the β auto-tuner and is serialized in
        // spill records; divergence confined to it must be visible to
        // the epoch handshake and the record integrity check.
        let mut a = LayerState::default();
        let mut b = LayerState::default();
        a.absorb(&[1.0, -2.0]);
        a.absorb(&[1.5, -1.0]);
        b.absorb(&[1.0, -2.0]);
        b.absorb(&[1.5, -1.0]);
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.prev_prev_abs.as_mut().unwrap()[0] = 9.0;
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn reset_clears() {
        let mut st = LayerState::default();
        st.memory = vec![1.0];
        st.pred = 3;
        st.eb = 0x3c23d70a;
        st.absorb(&[1.0]);
        assert!(!st.is_empty());
        st.reset();
        assert!(st.memory.is_empty() && st.prev_recon.is_none());
        assert_eq!(st.pred, 0);
        assert_eq!(st.eb, 0);
        assert!(st.is_empty());
    }

    #[test]
    fn fingerprint_covers_pred_tag() {
        // Identical buffers under different predictor selectors must not
        // check as the same state — the evict→reload / StateCheck
        // discriminator the self-describing-frame redesign relies on.
        let mut a = LayerState::default();
        let mut b = LayerState::default();
        a.absorb(&[1.0, -2.0]);
        b.absorb(&[1.0, -2.0]);
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.pred = 3;
        assert_ne!(a.fingerprint(), b.fingerprint());
        // The tag alone does not make a state warm: an empty layer is
        // cold regardless of configuration.
        let mut cs = CodecState::default();
        cs.ensure(2);
        cs.layers[1].pred = 3;
        assert_eq!(cs.fingerprint(), CodecState::default().fingerprint());
    }

    #[test]
    fn fingerprint_covers_eb_bits() {
        // Identical buffers written under different error bounds must
        // not check as the same state — the evict→reload / StateCheck
        // discriminator the `ebc=` controllers rely on.
        let mut a = LayerState::default();
        let mut b = LayerState::default();
        a.absorb(&[1.0, -2.0]);
        b.absorb(&[1.0, -2.0]);
        a.eb = crate::compress::quant::ErrorBound::Rel(1e-2).state_bits();
        b.eb = a.eb;
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.eb = crate::compress::quant::ErrorBound::Rel(5e-3).state_bits();
        assert_ne!(a.fingerprint(), b.fingerprint());
        // Mode matters too, not just magnitude.
        b.eb = crate::compress::quant::ErrorBound::Abs(1e-2).state_bits();
        assert_ne!(a.fingerprint(), b.fingerprint());
        // Like pred, the eb tag alone does not make a state warm.
        let mut cs = CodecState::default();
        cs.ensure(2);
        cs.layers[1].eb = a.eb;
        assert_eq!(cs.fingerprint(), CodecState::default().fingerprint());
    }

    #[test]
    fn codec_state_ensure() {
        let mut cs = CodecState::default();
        cs.ensure(3);
        assert_eq!(cs.layers.len(), 3);
        cs.ensure(2);
        assert_eq!(cs.layers.len(), 3);
    }

    #[test]
    fn cold_fingerprint_is_structural_not_positional() {
        // ensure() and reset() leave the fingerprint at the cold value:
        // the resync handshake treats "fresh", "ensured" and "reset"
        // states as the same cold epoch.
        let cold = CodecState::default().fingerprint();
        let mut cs = CodecState::default();
        cs.ensure(5);
        assert_eq!(cs.fingerprint(), cold);
        cs.layers[2].absorb(&[1.0, -1.0]);
        assert_ne!(cs.fingerprint(), cold);
        cs.reset();
        assert_eq!(cs.fingerprint(), cold);
    }

    #[test]
    fn fingerprint_mixes_layer_position() {
        let mut a = CodecState::default();
        a.ensure(2);
        a.layers[0].absorb(&[3.0]);
        let mut b = CodecState::default();
        b.ensure(2);
        b.layers[1].absorb(&[3.0]);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn epoch_cold_and_advance() {
        let mut e = StateEpoch::cold();
        assert!(e.is_cold());
        assert_eq!(e, StateEpoch::default());
        let mut cs = CodecState::default();
        cs.ensure(1);
        cs.layers[0].absorb(&[1.0]);
        e.advance(cs.fingerprint());
        assert!(!e.is_cold());
        assert_eq!(e.rounds, 1);
        assert_eq!(e.fingerprint, cs.fingerprint());
    }

    #[test]
    fn byte_size_counts_buffers() {
        let mut st = LayerState::default();
        assert_eq!(st.byte_size(), 0);
        st.absorb(&[1.0, 2.0, 3.0]);
        // prev_recon + prev_sign + prev_abs, 3 f32 each.
        assert_eq!(st.byte_size(), 3 * 3 * 4);
        st.memory = vec![0.0; 3];
        st.absorb(&[1.0, 2.0, 3.0]); // shifts prev_abs into prev_prev_abs
        assert_eq!(st.byte_size(), 3 * 4 + 4 * 3 * 4);
        let cs = ClientState { codec: CodecState { layers: vec![st] }, epoch: StateEpoch::cold() };
        assert_eq!(cs.byte_size(), 3 * 4 + 4 * 3 * 4);
    }

    #[test]
    fn rebuild_derived_matches_absorb() {
        let mut a = LayerState::default();
        a.absorb(&[0.5, -0.25, 0.0, -3.75]);
        let mut b = LayerState {
            memory: a.memory.clone(),
            prev_recon: a.prev_recon.clone(),
            prev_prev_abs: a.prev_prev_abs.clone(),
            ..Default::default()
        };
        b.rebuild_derived();
        assert_eq!(a.prev_sign, b.prev_sign);
        assert_eq!(a.prev_abs, b.prev_abs);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }
}
