//! Per-layer cross-round predictor state, mirrored on client and server.
//!
//! Both sides update their state **only** from data derivable from the
//! payload (reconstructed gradients), so after every round the two copies
//! are bit-identical — asserted by the `state_sync` integration test.

/// State for one layer.
#[derive(Debug, Clone, Default)]
pub struct LayerState {
    /// EMA memory `m` of Alg. 1 (empty until round 2).
    pub memory: Vec<f32>,
    /// Previous reconstructed gradient `g̃^(t-1)`.
    pub prev_recon: Option<Vec<f32>>,
    /// Previous sign tensor (sign of `g̃^(t-1)`), used by full-batch mode.
    pub prev_sign: Option<Vec<f32>>,
    /// Previous absolute reconstruction `|g̃^(t-1)|` (cached for Alg. 1).
    pub prev_abs: Option<Vec<f32>>,
    /// `|g̃^(t-2)|` — feeds the deterministic β auto-tuner (both sides
    /// hold identical copies; see `compress::autotune`).
    pub prev_prev_abs: Option<Vec<f32>>,
}

impl LayerState {
    /// Absorb this round's reconstruction, reusing existing buffer
    /// capacity (this runs once per layer per round on both sides).
    pub fn absorb(&mut self, recon: &[f32]) {
        fn refill(slot: &mut Option<Vec<f32>>, src: impl Iterator<Item = f32>, n: usize) {
            let buf = slot.get_or_insert_with(|| Vec::with_capacity(n));
            buf.clear();
            buf.extend(src);
        }
        let n = recon.len();
        // Shift the |g̃| history (swap keeps the old buffer's capacity).
        std::mem::swap(&mut self.prev_prev_abs, &mut self.prev_abs);
        refill(&mut self.prev_sign, recon.iter().map(|&x| {
            if x > 0.0 {
                1.0
            } else if x < 0.0 {
                -1.0
            } else {
                0.0
            }
        }), n);
        refill(&mut self.prev_abs, recon.iter().map(|x| x.abs()), n);
        refill(&mut self.prev_recon, recon.iter().copied(), n);
    }

    pub fn reset(&mut self) {
        self.memory.clear();
        self.prev_recon = None;
        self.prev_sign = None;
        self.prev_abs = None;
        self.prev_prev_abs = None;
    }

    /// Digest of the state for sync checks (cheap structural fingerprint).
    pub fn fingerprint(&self) -> u64 {
        fn mix(h: u64, bits: u32) -> u64 {
            (h ^ bits as u64).wrapping_mul(0x100000001b3)
        }
        let mut h = 0xcbf29ce484222325u64;
        for v in &self.memory {
            h = mix(h, v.to_bits());
        }
        if let Some(r) = &self.prev_recon {
            for v in r {
                h = mix(h, v.to_bits());
            }
        }
        h
    }
}

/// All layers of one peer's codec state.
#[derive(Debug, Clone, Default)]
pub struct CodecState {
    pub layers: Vec<LayerState>,
}

impl CodecState {
    /// Ensure `n` layer slots exist.
    pub fn ensure(&mut self, n: usize) {
        while self.layers.len() < n {
            self.layers.push(LayerState::default());
        }
    }
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.reset();
        }
    }
    pub fn fingerprint(&self) -> u64 {
        self.layers
            .iter()
            .fold(0xcbf29ce484222325u64, |h, l| h.wrapping_mul(31).wrapping_add(l.fingerprint()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_populates_all_views() {
        let mut st = LayerState::default();
        st.absorb(&[1.5, -2.0, 0.0]);
        assert_eq!(st.prev_recon.as_deref(), Some(&[1.5, -2.0, 0.0][..]));
        assert_eq!(st.prev_sign.as_deref(), Some(&[1.0, -1.0, 0.0][..]));
        assert_eq!(st.prev_abs.as_deref(), Some(&[1.5, 2.0, 0.0][..]));
    }

    #[test]
    fn fingerprint_distinguishes_states() {
        let mut a = LayerState::default();
        let mut b = LayerState::default();
        a.absorb(&[1.0, 2.0]);
        b.absorb(&[1.0, 2.0]);
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.absorb(&[1.0, 2.5]);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn reset_clears() {
        let mut st = LayerState::default();
        st.memory = vec![1.0];
        st.absorb(&[1.0]);
        st.reset();
        assert!(st.memory.is_empty() && st.prev_recon.is_none());
    }

    #[test]
    fn codec_state_ensure() {
        let mut cs = CodecState::default();
        cs.ensure(3);
        assert_eq!(cs.layers.len(), 3);
        cs.ensure(2);
        assert_eq!(cs.layers.len(), 3);
    }
}
