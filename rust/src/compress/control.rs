//! Adaptive error-bound control: a server-side controller that picks the
//! round's error bound (optionally per layer) from observed signals, plus
//! the versioned wire record that ships the decision to every client.
//!
//! The contract (DESIGN.md §15) is deliberately narrow:
//!
//! - The **server** consults its [`EbController`] exactly once per round,
//!   *before* the params broadcast, and obtains an [`EbPlan`].
//! - The plan is serialized as an `EBP` record ([`EbPlan::to_wire`]) and
//!   broadcast ahead of `GlobalParams` / `DeltaBegin`. Clients apply it to
//!   their codec before encoding; the server applies the same plan to its
//!   decode engines. Nothing else about the round changes.
//! - Decode needs **zero out-of-band eb config**: every lossy section
//!   already self-describes its Δ on the wire, so the plan only steers
//!   encode-side quantizer choice and the mirror fingerprint fold.
//! - Controllers are **deterministic pure functions** of the signals they
//!   observed ([`EbSignals`]) — replaying the same run re-derives the same
//!   plans, which the churn tests rely on.
//!
//! `ebc=fixed` (the default) produces no plan at all: no wire record is
//! broadcast and the message sequence is byte-identical to a build without
//! this module.

use crate::compress::quant::ErrorBound;
use anyhow::{bail, Result};

/// Wire-format version of the `EBP` record. Decoders reject anything newer.
pub const EBP_VERSION: u8 = 1;

/// One round's error-bound decision.
///
/// `round_eb` is the uniform bound for every layer; `per_layer`, when set,
/// overrides it layer-by-layer (indexed by layer position in the model).
/// Values are *magnitudes* — the abs/rel mode of the run's base
/// [`ErrorBound`] is preserved by [`EbPlan::bound_for`], so a plan can
/// never flip a binsum-eligible `abs` spec into a rel one mid-run.
#[derive(Debug, Clone, PartialEq)]
pub struct EbPlan {
    pub round_eb: f32,
    pub per_layer: Option<Vec<f32>>,
}

impl EbPlan {
    /// A uniform plan: every layer at `eb`.
    pub fn uniform(eb: f32) -> Self {
        EbPlan { round_eb: eb, per_layer: None }
    }

    /// The effective bound for layer `layer`, preserving `base`'s mode.
    pub fn bound_for(&self, base: ErrorBound, layer: usize) -> ErrorBound {
        let eb = self
            .per_layer
            .as_ref()
            .and_then(|v| v.get(layer).copied())
            .unwrap_or(self.round_eb) as f64;
        match base {
            ErrorBound::Abs(_) => ErrorBound::Abs(eb),
            ErrorBound::Rel(_) => ErrorBound::Rel(eb),
        }
    }

    /// Serialize as a versioned `EBP` record:
    /// `[version u8][round_eb f32 LE][has_layers u8][n u32 LE][eb f32 LE]*n`.
    pub fn to_wire(&self) -> Vec<u8> {
        let n = self.per_layer.as_ref().map_or(0, Vec::len);
        let mut out = Vec::with_capacity(1 + 4 + 1 + 4 + 4 * n);
        out.push(EBP_VERSION);
        out.extend_from_slice(&self.round_eb.to_le_bytes());
        match &self.per_layer {
            None => out.push(0),
            Some(v) => {
                out.push(1);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for eb in v {
                    out.extend_from_slice(&eb.to_le_bytes());
                }
            }
        }
        out
    }

    /// Parse an `EBP` record, rejecting unknown versions and any
    /// non-finite or non-positive bound.
    pub fn from_wire(buf: &[u8]) -> Result<EbPlan> {
        fn take<'a>(buf: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
            if buf.len() < n {
                bail!("eb plan: truncated record");
            }
            let (head, tail) = buf.split_at(n);
            *buf = tail;
            Ok(head)
        }
        fn check(eb: f32) -> Result<f32> {
            if !eb.is_finite() || eb <= 0.0 {
                bail!("eb plan: invalid error bound {eb}");
            }
            Ok(eb)
        }
        let mut r = buf;
        let version = take(&mut r, 1)?[0];
        if version != EBP_VERSION {
            bail!("eb plan: unknown version {version} (max {EBP_VERSION})");
        }
        let round_eb = check(f32::from_le_bytes(take(&mut r, 4)?.try_into().unwrap()))?;
        let per_layer = match take(&mut r, 1)?[0] {
            0 => None,
            1 => {
                let n = u32::from_le_bytes(take(&mut r, 4)?.try_into().unwrap()) as usize;
                if n > 1 << 20 {
                    bail!("eb plan: implausible layer count {n}");
                }
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(check(f32::from_le_bytes(take(&mut r, 4)?.try_into().unwrap()))?);
                }
                Some(v)
            }
            f => bail!("eb plan: bad per-layer flag {f}"),
        };
        if !r.is_empty() {
            bail!("eb plan: {} trailing bytes", r.len());
        }
        Ok(EbPlan { round_eb, per_layer })
    }
}

/// What the server feeds back to the controller after each round.
#[derive(Debug, Clone)]
pub struct EbSignals {
    pub round: u32,
    /// Mean training loss across this round's participants.
    pub train_loss: f64,
    /// `(loss, accuracy)` from the eval pass, when one ran this round.
    pub eval: Option<(f32, f32)>,
    /// Measured compressed bytes per layer (from `LayerReport`), summed
    /// over participants. Empty when the run doesn't collect reports.
    pub layer_bytes: Vec<usize>,
}

/// Server-side per-round error-bound policy.
///
/// `plan` is called once per round before the broadcast; `None` means
/// "no change from the configured bound — broadcast nothing" (the fixed
/// controller always answers this). `observe` is called after the round
/// with the measured signals.
pub trait EbController: Send {
    fn name(&self) -> &'static str;
    fn plan(&mut self, round: u32) -> Option<EbPlan>;
    fn observe(&mut self, sig: &EbSignals);
}

/// Parsed `ebc=` spec. `Display` round-trips through `parse`.
#[derive(Debug, Clone, PartialEq)]
pub enum EbcSpec {
    Fixed,
    /// `(round, eb)` milestones, strictly increasing rounds. The plan for
    /// round r is the last milestone with `round <= r` (or the base eb
    /// before the first milestone).
    Schedule(Vec<(u32, f32)>),
    Plateau {
        patience: u32,
        factor: f32,
    },
    Layerwise,
}

/// Registry rows for `fedgec codecs`: `(spec, summary)`.
pub const EBC_REGISTRY: &[(&str, &str)] = &[
    ("fixed", "configured eb for every round (default; no wire record)"),
    ("schedule:<r:eb,...>", "piecewise-constant eb milestones by round"),
    ("plateau[:patience,factor]", "tighten eb when the loss stops improving"),
    ("layerwise", "scale eb per layer by its measured byte share"),
];

impl EbcSpec {
    pub fn parse(spec: &str) -> Result<EbcSpec> {
        let (head, rest) = match spec.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (spec, None),
        };
        match (head, rest) {
            ("fixed", None) => Ok(EbcSpec::Fixed),
            ("layerwise", None) => Ok(EbcSpec::Layerwise),
            ("plateau", rest) => {
                let (patience, factor) = match rest {
                    None => (2, 0.5),
                    Some(r) => {
                        let (p, f) = r
                            .split_once(',')
                            .ok_or_else(|| anyhow::anyhow!("ebc: plateau wants patience,factor, got {r:?}"))?;
                        let patience: u32 = p
                            .trim()
                            .parse()
                            .map_err(|_| anyhow::anyhow!("ebc: bad plateau patience {p:?}"))?;
                        let factor: f32 = f
                            .trim()
                            .parse()
                            .map_err(|_| anyhow::anyhow!("ebc: bad plateau factor {f:?}"))?;
                        (patience, factor)
                    }
                };
                if patience == 0 {
                    bail!("ebc: plateau patience must be >= 1");
                }
                if !factor.is_finite() || factor <= 0.0 || factor >= 1.0 {
                    bail!("ebc: plateau factor must be in (0, 1), got {factor}");
                }
                Ok(EbcSpec::Plateau { patience, factor })
            }
            ("schedule", Some(r)) => {
                let mut points = Vec::new();
                for part in r.split(',') {
                    let (rnd, eb) = part
                        .split_once(':')
                        .ok_or_else(|| anyhow::anyhow!("ebc: schedule wants round:eb pairs, got {part:?}"))?;
                    let rnd: u32 = rnd
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("ebc: bad schedule round {rnd:?}"))?;
                    let eb: f32 = eb
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("ebc: bad schedule eb {eb:?}"))?;
                    if !eb.is_finite() || eb <= 0.0 {
                        bail!("ebc: schedule eb must be a finite positive number, got {eb}");
                    }
                    if let Some(&(prev, _)) = points.last() {
                        if rnd <= prev {
                            bail!("ebc: schedule rounds must be strictly increasing ({prev} then {rnd})");
                        }
                    }
                    points.push((rnd, eb));
                }
                if points.is_empty() {
                    bail!("ebc: schedule needs at least one round:eb pair");
                }
                Ok(EbcSpec::Schedule(points))
            }
            ("schedule", None) => bail!("ebc: schedule needs round:eb pairs"),
            _ => bail!(
                "ebc: unknown controller {spec:?} (expected fixed|schedule:<r:eb,...>|plateau[:patience,factor]|layerwise)"
            ),
        }
    }

    pub fn is_fixed(&self) -> bool {
        matches!(self, EbcSpec::Fixed)
    }

    /// Instantiate the controller. `base_eb` is the run's configured bound
    /// magnitude (`rel_error_bound` or the codec spec's eb).
    pub fn build(&self, base_eb: f64) -> Box<dyn EbController> {
        match self {
            EbcSpec::Fixed => Box::new(FixedCtl),
            EbcSpec::Schedule(points) => Box::new(ScheduleCtl { points: points.clone() }),
            EbcSpec::Plateau { patience, factor } => Box::new(PlateauCtl {
                patience: *patience,
                factor: *factor,
                base: base_eb as f32,
                cur: base_eb as f32,
                best: f64::INFINITY,
                streak: 0,
            }),
            EbcSpec::Layerwise => Box::new(LayerwiseCtl {
                base: base_eb as f32,
                layer_bytes: Vec::new(),
            }),
        }
    }
}

impl std::fmt::Display for EbcSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EbcSpec::Fixed => write!(f, "fixed"),
            EbcSpec::Layerwise => write!(f, "layerwise"),
            EbcSpec::Plateau { patience, factor } => write!(f, "plateau:{patience},{factor}"),
            EbcSpec::Schedule(points) => {
                write!(f, "schedule:")?;
                for (i, (r, eb)) in points.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{r}:{eb}")?;
                }
                Ok(())
            }
        }
    }
}

/// `ebc=fixed`: never plans, never broadcasts.
struct FixedCtl;

impl EbController for FixedCtl {
    fn name(&self) -> &'static str {
        "fixed"
    }
    fn plan(&mut self, _round: u32) -> Option<EbPlan> {
        None
    }
    fn observe(&mut self, _sig: &EbSignals) {}
}

/// `ebc=schedule:<r:eb,...>`: piecewise-constant milestones.
struct ScheduleCtl {
    points: Vec<(u32, f32)>,
}

impl EbController for ScheduleCtl {
    fn name(&self) -> &'static str {
        "schedule"
    }
    fn plan(&mut self, round: u32) -> Option<EbPlan> {
        self.points
            .iter()
            .rev()
            .find(|(r, _)| *r <= round)
            .map(|&(_, eb)| EbPlan::uniform(eb))
    }
    fn observe(&mut self, _sig: &EbSignals) {}
}

/// `ebc=plateau`: multiply the current eb by `factor` after `patience`
/// consecutive rounds without loss improvement. A loose bound buys cheap
/// early rounds; the controller tightens as training converges, so the
/// final metric matches a tight fixed bound at lower total bytes.
struct PlateauCtl {
    patience: u32,
    factor: f32,
    base: f32,
    cur: f32,
    best: f64,
    streak: u32,
}

impl PlateauCtl {
    /// Never drift more than four factor steps from the base in either
    /// direction — a runaway plateau signal cannot zero out the bound.
    fn clamp(&self, eb: f32) -> f32 {
        let span = self.factor.powi(4);
        let lo = self.base * span.min(1.0 / span);
        let hi = self.base * span.max(1.0 / span);
        eb.clamp(lo, hi)
    }
}

impl EbController for PlateauCtl {
    fn name(&self) -> &'static str {
        "plateau"
    }
    fn plan(&mut self, _round: u32) -> Option<EbPlan> {
        Some(EbPlan::uniform(self.cur))
    }
    fn observe(&mut self, sig: &EbSignals) {
        let loss = sig.eval.map_or(sig.train_loss, |(l, _)| l as f64);
        if !loss.is_finite() {
            return;
        }
        // The infinite-best guard matters: `INF - 1e-6 * INF` is NaN and
        // every comparison against it is false, so without it the first
        // observation could never register as an improvement.
        if !self.best.is_finite() || loss < self.best - 1e-6 * self.best.abs() {
            self.best = loss;
            self.streak = 0;
        } else {
            self.streak += 1;
            if self.streak >= self.patience {
                self.cur = self.clamp(self.cur * self.factor);
                self.streak = 0;
            }
        }
    }
}

/// `ebc=layerwise`: scale each layer's eb by the square root of its share
/// of the measured compressed bytes — heavy layers get a looser bound,
/// light layers a tighter one, clamped to [0.5, 2.0]× the base.
struct LayerwiseCtl {
    base: f32,
    layer_bytes: Vec<usize>,
}

impl EbController for LayerwiseCtl {
    fn name(&self) -> &'static str {
        "layerwise"
    }
    fn plan(&mut self, _round: u32) -> Option<EbPlan> {
        if self.layer_bytes.is_empty() {
            return Some(EbPlan::uniform(self.base));
        }
        let total: usize = self.layer_bytes.iter().sum();
        if total == 0 {
            return Some(EbPlan::uniform(self.base));
        }
        let mean_share = 1.0 / self.layer_bytes.len() as f64;
        let per_layer = self
            .layer_bytes
            .iter()
            .map(|&b| {
                let share = b as f64 / total as f64;
                let scale = (share / mean_share).sqrt().clamp(0.5, 2.0);
                self.base * scale as f32
            })
            .collect();
        Some(EbPlan { round_eb: self.base, per_layer: Some(per_layer) })
    }
    fn observe(&mut self, sig: &EbSignals) {
        if !sig.layer_bytes.is_empty() {
            self.layer_bytes = sig.layer_bytes.clone();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_roundtrips_uniform_and_layered() {
        for plan in [
            EbPlan::uniform(3e-2),
            EbPlan { round_eb: 1e-2, per_layer: Some(vec![5e-3, 2e-2, 1e-2]) },
        ] {
            let back = EbPlan::from_wire(&plan.to_wire()).unwrap();
            assert_eq!(back, plan);
        }
    }

    #[test]
    fn wire_rejects_bad_records() {
        let good = EbPlan::uniform(1e-2).to_wire();
        // Unknown version.
        let mut v = good.clone();
        v[0] = EBP_VERSION + 1;
        assert!(EbPlan::from_wire(&v).is_err());
        // Truncated.
        assert!(EbPlan::from_wire(&good[..good.len() - 1]).is_err());
        // Trailing garbage.
        let mut t = good.clone();
        t.push(0);
        assert!(EbPlan::from_wire(&t).is_err());
        // Non-positive / non-finite bounds.
        for bad in [0.0f32, -1e-2, f32::NAN, f32::INFINITY] {
            let w = EbPlan::uniform(bad).to_wire();
            assert!(EbPlan::from_wire(&w).is_err(), "accepted eb {bad}");
        }
        // Bad per-layer flag.
        let mut f = good;
        let last = f.len() - 1;
        f[last] = 7;
        assert!(EbPlan::from_wire(&f).is_err());
    }

    #[test]
    fn bound_for_preserves_mode_and_indexes_layers() {
        let plan = EbPlan { round_eb: 1e-2, per_layer: Some(vec![5e-3, 2e-2]) };
        assert_eq!(plan.bound_for(ErrorBound::Rel(9.0), 0), ErrorBound::Rel(5e-3f32 as f64));
        assert_eq!(plan.bound_for(ErrorBound::Abs(9.0), 1), ErrorBound::Abs(2e-2f32 as f64));
        // Out-of-range layer falls back to the round eb.
        assert_eq!(plan.bound_for(ErrorBound::Rel(9.0), 5), ErrorBound::Rel(1e-2f32 as f64));
    }

    #[test]
    fn spec_parse_display_roundtrip() {
        for s in ["fixed", "layerwise", "plateau:3,0.25", "schedule:0:0.03,10:0.01"] {
            let spec = EbcSpec::parse(s).unwrap();
            assert_eq!(EbcSpec::parse(&spec.to_string()).unwrap(), spec);
        }
        assert_eq!(
            EbcSpec::parse("plateau").unwrap(),
            EbcSpec::Plateau { patience: 2, factor: 0.5 }
        );
    }

    #[test]
    fn spec_parse_rejects_malformed() {
        for s in [
            "nope",
            "schedule",
            "schedule:abc",
            "schedule:5:0.01,3:0.02", // rounds not increasing
            "schedule:0:nan",
            "schedule:0:-1.0",
            "plateau:0,0.5",  // zero patience
            "plateau:2,1.5",  // factor >= 1
            "plateau:2,-0.5", // factor <= 0
            "plateau:2",      // missing factor
        ] {
            assert!(EbcSpec::parse(s).is_err(), "accepted {s:?}");
        }
    }

    #[test]
    fn fixed_never_plans() {
        let mut c = EbcSpec::Fixed.build(1e-2);
        for r in 0..5 {
            assert!(c.plan(r).is_none());
        }
    }

    #[test]
    fn schedule_picks_last_milestone_at_or_before_round() {
        let mut c = EbcSpec::parse("schedule:2:0.03,5:0.01").unwrap().build(1e-2);
        assert!(c.plan(0).is_none(), "before the first milestone: no plan");
        assert_eq!(c.plan(2).unwrap().round_eb, 0.03);
        assert_eq!(c.plan(4).unwrap().round_eb, 0.03);
        assert_eq!(c.plan(5).unwrap().round_eb, 0.01);
        assert_eq!(c.plan(99).unwrap().round_eb, 0.01);
    }

    #[test]
    fn plateau_tightens_after_patience_and_clamps() {
        let mut c = EbcSpec::Plateau { patience: 2, factor: 0.5 }.build(0.1);
        let sig = |round, loss: f64| EbSignals {
            round,
            train_loss: loss,
            eval: None,
            layer_bytes: vec![],
        };
        assert_eq!(c.plan(0).unwrap().round_eb, 0.1);
        c.observe(&sig(0, 1.0)); // improvement (vs +inf)
        c.observe(&sig(1, 1.0)); // flat x1
        assert_eq!(c.plan(2).unwrap().round_eb, 0.1);
        c.observe(&sig(2, 1.0)); // flat x2 -> tighten
        assert_eq!(c.plan(3).unwrap().round_eb, 0.05);
        // Keep flat-lining: eb floors at base * factor^4.
        for r in 3..40 {
            c.observe(&sig(r, 1.0));
        }
        let eb = c.plan(99).unwrap().round_eb;
        assert!((eb - 0.1 * 0.5f32.powi(4)).abs() < 1e-9, "clamped at {eb}");
    }

    #[test]
    fn plateau_prefers_eval_loss_and_resets_on_improvement() {
        let mut c = EbcSpec::Plateau { patience: 1, factor: 0.5 }.build(0.1);
        c.observe(&EbSignals {
            round: 0,
            train_loss: 99.0, // would look flat...
            eval: Some((1.0, 0.5)),
            layer_bytes: vec![],
        });
        c.observe(&EbSignals {
            round: 1,
            train_loss: 99.0,
            eval: Some((0.5, 0.6)), // ...but eval keeps improving
            layer_bytes: vec![],
        });
        assert_eq!(c.plan(2).unwrap().round_eb, 0.1, "improving run never tightens");
    }

    #[test]
    fn layerwise_scales_by_byte_share() {
        let mut c = EbcSpec::Layerwise.build(0.01);
        // No signal yet: uniform base.
        assert_eq!(c.plan(0).unwrap(), EbPlan::uniform(0.01));
        c.observe(&EbSignals {
            round: 0,
            train_loss: 1.0,
            eval: None,
            layer_bytes: vec![100, 100, 3800],
        });
        let plan = c.plan(1).unwrap();
        let per = plan.per_layer.expect("layered plan after observing bytes");
        assert_eq!(per.len(), 3);
        assert!(per[0] < 0.01, "light layer tightens: {}", per[0]);
        assert!(per[2] > 0.01, "heavy layer loosens: {}", per[2]);
        assert!(per[2] <= 0.01 * 2.0 + 1e-9, "clamped at 2x");
        // Deterministic: same signals, same plan.
        assert_eq!(c.plan(1).unwrap().per_layer.unwrap(), per);
    }
}
