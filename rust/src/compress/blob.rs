//! Binary wire helpers for payload headers: a tiny, dependency-free
//! writer/reader over little-endian primitives and length-prefixed byte
//! sections. All compressed-round payloads are built from these.
//!
//! # Layer-section format versions
//!
//! Every codec's per-layer section (the bytes closed by the lossless
//! backend) opens with one of these tags:
//!
//! | tag | meaning                                                        |
//! |-----|----------------------------------------------------------------|
//! | 0   | lossless small-layer store (raw f32s)                          |
//! | 1   | lossy v1: implicit Huffman entropy stage (seed format)         |
//! | 2   | lossy v2: explicit entropy-coder tag byte follows the header   |
//! | 3   | lossy v3: coder tag + magnitude-predictor tag (+ EMA β)        |
//!
//! v1 is still written whenever the Huffman coder is selected *and* the
//! magnitude predictor is the implicit config-driven EMA, keeping the
//! default pipeline byte-compatible with the seed; a non-Huffman coder
//! bumps the section to v2 and records its
//! [`crate::compress::EntropyCoder::tag`]; a non-implicit magnitude
//! predictor (`pred=last|zero|auto`) bumps to v3, which always records
//! the coder tag plus the
//! [`crate::compress::predictor::magnitude::PredTag`] actually used
//! (and, for EMA, the effective β as an exact f32) — the decoder then
//! reconstructs the layer with zero out-of-band configuration.

use crate::compress::entropy::EntropyCoder;
use crate::compress::predictor::magnitude::PredTag;

/// Layer-section tag: lossless small-layer store.
pub const SECTION_LOSSLESS: u8 = 0;
/// Layer-section tag: lossy, v1 (implicit Huffman entropy stage).
pub const SECTION_LOSSY_V1: u8 = 1;
/// Layer-section tag: lossy, v2 (explicit entropy-coder tag).
pub const SECTION_LOSSY_V2: u8 = 2;
/// Layer-section tag: lossy, v3 (self-describing predictor frames:
/// explicit coder tag + magnitude-predictor tag, + EMA β when used).
pub const SECTION_LOSSY_V3: u8 = 3;
/// Current layer-section format version (the highest tag we emit).
pub const BLOB_VERSION: u8 = SECTION_LOSSY_V3;

/// Section tag for a lossy layer closed by `coder` under the implicit
/// (config-driven EMA) predictor: Huffman keeps the seed-compatible v1
/// tag, anything else bumps to v2. Self-describing predictor sections
/// open with [`put_pred_header`] instead.
pub fn section_tag_for(coder: EntropyCoder) -> u8 {
    if coder == EntropyCoder::Huffman {
        SECTION_LOSSY_V1
    } else {
        SECTION_LOSSY_V2
    }
}

/// Open a v3 (self-describing predictor) lossy section: section tag,
/// coder tag, predictor wire tag, and — for the EMA predictor — the
/// effective β as an exact f32 so the decoder's memory update is
/// bit-identical with zero out-of-band configuration. Pairs with
/// [`read_pred_suffix`] after [`read_section_coder`] consumed the coder
/// byte.
pub fn put_pred_header(w: &mut BlobWriter, coder: EntropyCoder, pred: PredTag, beta: f32) {
    w.put_u8(SECTION_LOSSY_V3);
    w.put_u8(coder.tag());
    w.put_u8(pred.tag());
    if pred == PredTag::Ema {
        w.put_f32(beta);
    }
}

/// Read the predictor half of a v3 header (the coder byte was already
/// consumed by [`read_section_coder`]): returns the wire tag and the
/// recorded β (0 for non-EMA predictors, which carry none).
pub fn read_pred_suffix(r: &mut BlobReader) -> anyhow::Result<(PredTag, f32)> {
    let tag = PredTag::from_tag(r.get_u8()?)?;
    let beta = if tag == PredTag::Ema { r.get_f32()? } else { 0.0 };
    Ok((tag, beta))
}

/// Write the coder byte a v2 section records (nothing for v1 — Huffman
/// is implicit there). Pairs with [`read_section_coder`]; every codec's
/// writer goes through this so a future version bump happens here, not
/// per codec.
pub fn put_coder_suffix(w: &mut BlobWriter, coder: EntropyCoder) {
    if section_tag_for(coder) == SECTION_LOSSY_V2 {
        w.put_u8(coder.tag());
    }
}

/// Resolve the entropy coder a lossy section tag records — the decoder
/// dispatch point shared by every codec: v1 is implicitly Huffman, v2
/// reads the recorded coder byte, anything else is rejected.
pub fn read_section_coder(r: &mut BlobReader, tag: u8) -> anyhow::Result<EntropyCoder> {
    match tag {
        SECTION_LOSSY_V1 => Ok(EntropyCoder::Huffman),
        SECTION_LOSSY_V2 | SECTION_LOSSY_V3 => EntropyCoder::from_tag(r.get_u8()?),
        t => anyhow::bail!("unknown layer-section tag {t}"),
    }
}

/// Append-only binary writer.
#[derive(Default)]
pub struct BlobWriter {
    buf: Vec<u8>,
}

impl BlobWriter {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// Length-prefixed byte section.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    /// Raw f32 slice (length-prefixed, little-endian).
    pub fn put_f32_slice(&mut self, v: &[f32]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    /// Raw f64 slice (length-prefixed, little-endian).
    pub fn put_f64_slice(&mut self, v: &[f64]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    /// Raw i64 slice (length-prefixed, little-endian).
    pub fn put_i64_slice(&mut self, v: &[i64]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Sequential binary reader with bounds checking.
pub struct BlobReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BlobReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BlobReader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            anyhow::bail!("blob underrun: want {n} at {} of {}", self.pos, self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    pub fn get_u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn get_u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn get_u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn get_f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn get_f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn get_bytes(&mut self) -> anyhow::Result<&'a [u8]> {
        let n = self.get_u32()? as usize;
        self.take(n)
    }
    pub fn get_f32_vec(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.get_u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    pub fn get_f64_vec(&mut self) -> anyhow::Result<Vec<f64>> {
        let n = self.get_u32()? as usize;
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }
    pub fn get_i64_vec(&mut self) -> anyhow::Result<Vec<i64>> {
        let n = self.get_u32()? as usize;
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect())
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    pub fn pos(&self) -> usize {
        self.pos
    }
}

/// Encode an f32 slice as raw little-endian bytes (no prefix).
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Decode raw little-endian bytes into f32s.
pub fn bytes_to_f32s(buf: &[u8]) -> anyhow::Result<Vec<f32>> {
    if buf.len() % 4 != 0 {
        anyhow::bail!("byte length {} not divisible by 4", buf.len());
    }
    Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_roundtrip() {
        let mut w = BlobWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEADBEEF);
        w.put_u64(u64::MAX);
        w.put_f32(1.5);
        w.put_f64(-2.25);
        w.put_bytes(b"hello");
        w.put_f32_slice(&[1.0, -2.0]);
        w.put_f64_slice(&[0.5, -1.0e300]);
        w.put_i64_slice(&[i64::MIN, -1, i64::MAX]);
        let bytes = w.into_bytes();
        let mut r = BlobReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_f32().unwrap(), 1.5);
        assert_eq!(r.get_f64().unwrap(), -2.25);
        assert_eq!(r.get_bytes().unwrap(), b"hello");
        assert_eq!(r.get_f32_vec().unwrap(), vec![1.0, -2.0]);
        assert_eq!(r.get_f64_vec().unwrap(), vec![0.5, -1.0e300]);
        assert_eq!(r.get_i64_vec().unwrap(), vec![i64::MIN, -1, i64::MAX]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn slice_vec_underruns_error_before_allocating() {
        // A length prefix far past the remaining bytes must fail the
        // bounds check (and not trust the prefix for allocation).
        let mut w = BlobWriter::new();
        w.put_u32(u32::MAX);
        let bytes = w.into_bytes();
        assert!(BlobReader::new(&bytes).get_i64_vec().is_err());
        assert!(BlobReader::new(&bytes).get_f64_vec().is_err());
        assert!(BlobReader::new(&bytes).get_f32_vec().is_err());
    }

    #[test]
    fn underrun_errors() {
        let mut r = BlobReader::new(&[1, 2]);
        assert!(r.get_u32().is_err());
    }

    #[test]
    fn section_helpers_roundtrip_every_coder() {
        for coder in EntropyCoder::ALL {
            let tag = section_tag_for(coder);
            let mut w = BlobWriter::new();
            put_coder_suffix(&mut w, coder);
            let bytes = w.into_bytes();
            // v1 (Huffman) writes no suffix byte — seed byte-compat.
            assert_eq!(bytes.is_empty(), coder == EntropyCoder::Huffman);
            let mut r = BlobReader::new(&bytes);
            assert_eq!(read_section_coder(&mut r, tag).unwrap(), coder);
            assert_eq!(r.remaining(), 0);
        }
        let mut r = BlobReader::new(&[]);
        assert!(read_section_coder(&mut r, 9).is_err());
        // A v2 tag with the suffix missing is a truncation error.
        let mut r = BlobReader::new(&[]);
        assert!(read_section_coder(&mut r, SECTION_LOSSY_V2).is_err());
    }

    #[test]
    fn pred_header_roundtrips_every_coder_and_tag() {
        for coder in EntropyCoder::ALL {
            for pred in PredTag::ALL {
                let mut w = BlobWriter::new();
                put_pred_header(&mut w, coder, pred, 0.875);
                let bytes = w.into_bytes();
                // EMA sections carry the 4-byte β; others stay lean.
                assert_eq!(bytes.len(), if pred == PredTag::Ema { 7 } else { 3 });
                let mut r = BlobReader::new(&bytes);
                let tag = r.get_u8().unwrap();
                assert_eq!(tag, SECTION_LOSSY_V3);
                assert_eq!(read_section_coder(&mut r, tag).unwrap(), coder);
                let (got_pred, got_beta) = read_pred_suffix(&mut r).unwrap();
                assert_eq!(got_pred, pred);
                assert_eq!(got_beta, if pred == PredTag::Ema { 0.875 } else { 0.0 });
                assert_eq!(r.remaining(), 0);
            }
        }
        // Truncated v3 headers error at every stage.
        let mut r = BlobReader::new(&[]);
        assert!(read_section_coder(&mut r, SECTION_LOSSY_V3).is_err());
        let mut r = BlobReader::new(&[]);
        assert!(read_pred_suffix(&mut r).is_err());
        let mut r = BlobReader::new(&[PredTag::Ema.tag()]);
        assert!(read_pred_suffix(&mut r).is_err(), "ema suffix without β is truncation");
        let mut r = BlobReader::new(&[9]);
        assert!(read_pred_suffix(&mut r).is_err(), "unknown predictor tag");
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let v = vec![0.0f32, -1.5, f32::NAN, 3.25e10];
        let b = f32s_to_bytes(&v);
        let got = bytes_to_f32s(&b).unwrap();
        assert_eq!(got.len(), v.len());
        assert!(got[2].is_nan());
        assert_eq!(got[3], v[3]);
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }
}
