//! The complete FedGEC codec — paper Algorithms 3 (client) and 4 (server).
//!
//! Per layer: small tensors (`numel ≤ T_LOSSY`) are stored losslessly;
//! large tensors run the four-stage lossy pipeline with the gradient-aware
//! predictor. The per-layer frame bundles `(μ_curr, σ_curr)`, the sign
//! side-info (flip bit or two-level bitmap), the Huffman-coded residual
//! codes and the escape values, and is closed by the lossless backend —
//! exactly the structure of Alg. 3 lines 6-16.
//!
//! The codec speaks the session/frame API: each layer's state is
//! independent, so [`FedgecCodec::encode_model`] compresses layers in
//! parallel on [`crate::util::threadpool`] for large models (the layer
//! pipeline the paper's deployment story needs).
//!
//! The predict stage can run on the native fused path
//! ([`crate::compress::fused`]) or through a pluggable
//! [`PredictBackend`] (the PJRT/HLO engine in `crate::runtime` that
//! executes the Pallas kernel's lowering).

use super::autotune::TauController;
use super::blob::{
    bytes_to_f32s, f32s_to_bytes, put_coder_suffix, read_section_coder, section_tag_for,
    BlobReader, BlobWriter, SECTION_LOSSLESS,
};
use super::entropy::EntropyCoder;
use super::frame::Frame;
use super::fused::{fused_decode, fused_encode, FusedEncodeOut, FusedParams};
use super::lossless::{self, Backend};
use super::predictor::sign::{predict_signs, reconstruct_signs, SignMeta, SignMode};
use super::quant::{self, ErrorBound, Quantized};
use super::state::{CodecState, LayerState};
use super::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};
use crate::util::stats;

pub use super::frame::LayerReport;

/// Tunable knobs of the codec (paper Alg. 3 parameter list).
#[derive(Debug, Clone)]
pub struct FedgecConfig {
    /// EMA decay factor β (paper α in Alg. 3; default 0.9).
    pub beta: f32,
    /// Kernel sign-consistency threshold τ (default 0.5, §5.4).
    pub tau: f64,
    /// Full-batch GD flag: oscillation sign mode instead of kernel mode.
    pub full_batch: bool,
    /// Error bound (ABS or REL).
    pub error_bound: ErrorBound,
    /// Layers with `numel ≤ t_lossy` are stored losslessly (Alg. 3 line 3).
    pub t_lossy: usize,
    /// Stage-3 entropy coder (spec key `ec`; Huffman keeps the seed's
    /// byte-compatible v1 sections, anything else writes v2).
    pub entropy: EntropyCoder,
    /// Stage-4 lossless backend.
    pub backend: Backend,
    /// Auto-tune τ (client-side controller) and β (deterministic
    /// history-derived schedule) — the paper's §6 extension. See
    /// [`super::autotune`].
    pub autotune: bool,
}

impl Default for FedgecConfig {
    fn default() -> Self {
        FedgecConfig {
            beta: 0.9,
            tau: 0.5,
            full_batch: false,
            error_bound: ErrorBound::Rel(1e-2),
            t_lossy: 1024,
            entropy: EntropyCoder::Huffman,
            backend: Backend::default(),
            autotune: false,
        }
    }
}

/// Pluggable predict-stage engine (see module docs). `memory` is updated
/// in place; returns `ĝ = S ⊙ â`.
pub trait PredictBackend: Send {
    fn predict(
        &mut self,
        prev_abs: &[f32],
        memory: &mut [f32],
        signs: &[f32],
        p: &FusedParams,
    ) -> anyhow::Result<Vec<f32>>;
}

/// The FedGEC codec: symmetric client/server object implementing
/// [`GradientCodec`].
pub struct FedgecCodec {
    pub cfg: FedgecConfig,
    pub state: CodecState,
    /// Optional PJRT/HLO predict engine; `None` ⇒ native fused path.
    pub engine: Option<Box<dyn PredictBackend>>,
    /// Per-layer τ controllers (client side, active when cfg.autotune).
    pub tau_ctrl: Vec<TauController>,
}

impl FedgecCodec {
    pub fn new(cfg: FedgecConfig) -> Self {
        FedgecCodec { cfg, state: CodecState::default(), engine: None, tau_ctrl: Vec::new() }
    }

    pub fn with_engine(cfg: FedgecConfig, engine: Box<dyn PredictBackend>) -> Self {
        FedgecCodec {
            cfg,
            state: CodecState::default(),
            engine: Some(engine),
            tau_ctrl: Vec::new(),
        }
    }

    fn ensure_ctrl(&mut self, n: usize) {
        if self.cfg.autotune && !self.cfg.full_batch {
            while self.tau_ctrl.len() < n {
                self.tau_ctrl.push(TauController { tau: self.cfg.tau, ..Default::default() });
            }
        }
    }

    /// Worker count for layer-parallel encoding (1 = stay sequential; the
    /// HLO engine path is inherently sequential).
    fn encode_threads(&self, grads: &ModelGrad) -> usize {
        if self.engine.is_some() {
            return 1;
        }
        crate::util::threadpool::layer_parallelism(grads.layers.len(), grads.numel())
    }
}

/// The effective β for a layer this round: config value, or the
/// deterministic history-derived schedule when auto-tuning (identical on
/// both sides — derived from reconstructed data only).
fn effective_beta(cfg: &FedgecConfig, st: &LayerState) -> f32 {
    if !cfg.autotune {
        return cfg.beta;
    }
    match (&st.prev_abs, &st.prev_prev_abs) {
        (Some(a), Some(b)) => super::autotune::beta_from_history(a, b),
        _ => cfg.beta,
    }
}

/// Compress one layer into its closed (post-lossless) frame payload.
/// Free-standing over the layer's own state so layers encode in parallel.
fn compress_layer_impl(
    cfg: &FedgecConfig,
    layer: &LayerGrad,
    st: &mut LayerState,
    ctrl: Option<&mut TauController>,
    engine: Option<&mut dyn PredictBackend>,
) -> crate::Result<(Vec<u8>, LayerReport)> {
    let grad = &layer.data;
    let n = grad.len();
    let mut report = LayerReport {
        name: layer.meta.name.clone(),
        raw_bytes: n * 4,
        ..Default::default()
    };
    let mut w = BlobWriter::new();

    if n <= cfg.t_lossy {
        // Alg. 3 line 3-4: lossless-only small layer (bypasses predictor
        // state entirely).
        w.put_u8(SECTION_LOSSLESS);
        w.put_bytes(&f32s_to_bytes(grad));
        let closed = cfg.backend.compress(&w.into_bytes())?;
        return Ok((closed, report));
    }
    report.lossy = true;

    // --- Stage 1a: sign prediction (Alg. 3 line 10). ---
    let mode = if cfg.full_batch {
        SignMode::FullBatch
    } else {
        SignMode::MiniBatch { tau: ctrl.as_ref().map(|c| c.tau).unwrap_or(cfg.tau) }
    };
    let beta = effective_beta(cfg, st);
    let (signs, sign_meta, sign_stats) = predict_signs(
        grad,
        &layer.meta.kind,
        mode,
        st.prev_recon.as_deref(),
        st.prev_sign.as_deref(),
    );
    report.sign_stats = sign_stats;
    if let Some(ctrl) = ctrl {
        if !cfg.full_batch && sign_stats.kernels_total > 0 {
            ctrl.update(sign_stats.mismatch_rate(), sign_stats.prediction_ratio());
        }
    }

    // --- Stage 1b+2: magnitude prediction + quantization. ---
    let (mu_curr, sigma_curr) = stats::mean_std_abs(grad);
    let (lo, hi) = stats::finite_min_max(grad);
    let delta = cfg.error_bound.resolve(lo, hi);
    let empty: [f32; 0] = [];
    let prev_abs: &[f32] = st.prev_abs.as_deref().unwrap_or(&empty);
    let (mu_prev, sigma_prev) = stats::mean_std(prev_abs);
    let p = FusedParams {
        beta,
        mu_curr,
        sigma_curr,
        mu_prev,
        sigma_prev,
        two_delta: (2.0 * delta) as f32,
        delta: delta as f32,
    };

    let mut out = FusedEncodeOut::default();
    match engine {
        None => {
            fused_encode(grad, prev_abs, &mut st.memory, &signs, &p, &mut out);
        }
        Some(engine) => {
            if !prev_abs.is_empty() && st.memory.len() != n {
                st.memory.clear();
                st.memory.resize(n, 0.0);
            }
            let ghat = if prev_abs.is_empty() {
                vec![0.0; n]
            } else {
                engine.predict(prev_abs, &mut st.memory, &signs, &p)?
            };
            let mut q = Quantized::default();
            quant::quantize(grad, &ghat, delta, &mut q, &mut out.recon);
            out.codes = q.codes;
            out.escapes = q.escapes;
        }
    }
    report.escape_count = out.escapes.len();

    // --- Stage 3: entropy coding. ---
    // The coder is a client-only decision (recorded in the section
    // header), so autotune may pick the cheaper one per layer with zero
    // synchronization cost. One histogram pass feeds both the choice and
    // the chosen encoder (§Perf: the code stream is layer-sized).
    let (coder, entropy) = if cfg.autotune && cfg.entropy != EntropyCoder::Raw {
        let hist = quant::code_histogram(&out.codes);
        let coder =
            super::autotune::pick_entropy_coder_from_hist(&hist, out.codes.len(), cfg.entropy);
        let entropy = coder.encode_to_bytes_with_hist(&out.codes, &hist);
        (coder, entropy)
    } else {
        let coder = cfg.entropy;
        (coder, coder.encode_to_bytes(&out.codes))
    };
    report.entropy_bytes = entropy.len();
    report.entropy_coder = coder.name().to_string();
    let sign_bytes = sign_meta.encode();
    report.side_info_bytes = sign_bytes.len() + out.escapes.len() * 4;

    // --- Layer section (Alg. 3 line 15; Huffman keeps v1 bytes). ---
    w.put_u8(section_tag_for(coder));
    put_coder_suffix(&mut w, coder);
    w.put_u32(n as u32);
    w.put_f32(mu_curr);
    w.put_f32(sigma_curr);
    w.put_f64(delta);
    w.put_bytes(&sign_bytes);
    w.put_bytes(&entropy);
    w.put_f32_slice(&out.escapes);

    // Update local state with the reconstruction (client mirror).
    st.absorb(&out.recon);
    let closed = cfg.backend.compress(&w.into_bytes())?;
    Ok((closed, report))
}

/// Decode one layer's frame section (post-lossless bytes).
fn decompress_layer_impl(
    cfg: &FedgecConfig,
    meta: &LayerMeta,
    section: &[u8],
    st: &mut LayerState,
    engine: Option<&mut dyn PredictBackend>,
) -> crate::Result<(Vec<f32>, LayerReport)> {
    let mut r = BlobReader::new(section);
    let tag = r.get_u8()?;
    let mut report = LayerReport { name: meta.name.clone(), ..Default::default() };
    if tag == SECTION_LOSSLESS {
        let data = bytes_to_f32s(r.get_bytes()?)?;
        anyhow::ensure!(data.len() == meta.numel, "layer {}: lossless numel", meta.name);
        report.raw_bytes = data.len() * 4;
        return Ok((data, report));
    }
    // Dispatch on the recorded coder: v1 sections are implicitly Huffman,
    // v2 sections carry the coder tag.
    let coder = read_section_coder(&mut r, tag)
        .map_err(|e| anyhow::anyhow!("layer {}: {e}", meta.name))?;
    report.lossy = true;
    report.entropy_coder = coder.name().to_string();
    let n = r.get_u32()? as usize;
    if n != meta.numel {
        anyhow::bail!("layer {}: payload numel {} != meta {}", meta.name, n, meta.numel);
    }
    report.raw_bytes = n * 4;
    let mu_curr = r.get_f32()?;
    let sigma_curr = r.get_f32()?;
    let delta = r.get_f64()?;
    let sign_bytes = r.get_bytes()?;
    let sign_meta = SignMeta::decode(sign_bytes)?;
    let entropy = r.get_bytes()?;
    report.entropy_bytes = entropy.len();
    // `n` is already validated against the trusted meta, so it bounds the
    // decode (a corrupt stream cannot declare an inflated symbol count).
    let (codes, _) = coder.decode_bounded(entropy, n)?;
    if codes.len() != n {
        anyhow::bail!("layer {}: {} codes for {} elements", meta.name, codes.len(), n);
    }
    let escapes = r.get_f32_vec()?;
    report.side_info_bytes = sign_bytes.len() + escapes.len() * 4;
    report.escape_count = escapes.len();

    let beta = effective_beta(cfg, st);
    let signs = reconstruct_signs(&sign_meta, n, &meta.kind, st.prev_sign.as_deref())?;
    let empty: [f32; 0] = [];
    let prev_abs: &[f32] = st.prev_abs.as_deref().unwrap_or(&empty);
    let (mu_prev, sigma_prev) = stats::mean_std(prev_abs);
    let p = FusedParams {
        beta,
        mu_curr,
        sigma_curr,
        mu_prev,
        sigma_prev,
        two_delta: (2.0 * delta) as f32,
        delta: delta as f32,
    };
    let mut recon = Vec::new();
    match engine {
        None => {
            fused_decode(&codes, &escapes, prev_abs, &mut st.memory, &signs, &p, &mut recon)?;
        }
        Some(engine) => {
            if !prev_abs.is_empty() && st.memory.len() != n {
                st.memory.clear();
                st.memory.resize(n, 0.0);
            }
            let ghat = if prev_abs.is_empty() {
                vec![0.0; n]
            } else {
                engine.predict(prev_abs, &mut st.memory, &signs, &p)?
            };
            let q = Quantized { codes, escapes };
            quant::dequantize(&q, &ghat, delta, &mut recon);
        }
    }
    st.absorb(&recon);
    Ok((recon, report))
}

/// The stateless decode engine of the FedGEC codec: configuration (plus
/// an optional PJRT/HLO predict backend) only — per-client predictor
/// state arrives explicitly with every call, so one engine serves an
/// entire federation (the server pairs it with a
/// [`crate::compress::store::StateStore`]).
pub struct FedgecEngine {
    pub cfg: FedgecConfig,
    /// Optional PJRT/HLO predict engine; `None` ⇒ native fused path.
    pub engine: Option<Box<dyn PredictBackend>>,
}

impl FedgecEngine {
    pub fn new(cfg: FedgecConfig) -> Self {
        FedgecEngine { cfg, engine: None }
    }

    pub fn with_engine(cfg: FedgecConfig, engine: Box<dyn PredictBackend>) -> Self {
        FedgecEngine { cfg, engine: Some(engine) }
    }
}

impl crate::compress::engine::CodecEngine for FedgecEngine {
    fn name(&self) -> &'static str {
        "fedgec"
    }

    fn stateful(&self) -> bool {
        true
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
        state: &mut CodecState,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        let idx = frame.index as usize;
        state.ensure(idx + 1);
        let section = lossless::decompress(&frame.payload)?;
        let (data, mut report) = decompress_layer_impl(
            &self.cfg,
            meta,
            &section,
            &mut state.layers[idx],
            self.engine.as_deref_mut(),
        )?;
        report.compressed_bytes = frame.wire_size();
        Ok((LayerGrad::new(meta.clone(), data), report))
    }
}

impl GradientCodec for FedgecCodec {
    fn begin(&mut self, n_layers: usize) -> crate::Result<()> {
        self.state.ensure(n_layers);
        self.ensure_ctrl(n_layers);
        Ok(())
    }

    fn encode_layer(&mut self, idx: usize, layer: &LayerGrad) -> crate::Result<Frame> {
        self.state.ensure(idx + 1);
        self.ensure_ctrl(idx + 1);
        let use_ctrl = self.cfg.autotune && !self.cfg.full_batch;
        let ctrl = if use_ctrl { Some(&mut self.tau_ctrl[idx]) } else { None };
        let (payload, report) = compress_layer_impl(
            &self.cfg,
            layer,
            &mut self.state.layers[idx],
            ctrl,
            self.engine.as_deref_mut(),
        )?;
        Ok(Frame::new(idx, payload, report))
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        let idx = frame.index as usize;
        self.state.ensure(idx + 1);
        let section = lossless::decompress(&frame.payload)?;
        let (data, mut report) = decompress_layer_impl(
            &self.cfg,
            meta,
            &section,
            &mut self.state.layers[idx],
            self.engine.as_deref_mut(),
        )?;
        report.compressed_bytes = frame.wire_size();
        Ok((LayerGrad::new(meta.clone(), data), report))
    }

    /// Layer-parallel whole-model encode: each layer's predictor state is
    /// independent, so large models fan out across worker threads.
    fn encode_model(&mut self, grads: &ModelGrad) -> crate::Result<Vec<Frame>> {
        let n = grads.layers.len();
        self.begin(n)?;
        let threads = self.encode_threads(grads);
        if threads <= 1 {
            let mut frames = Vec::with_capacity(n);
            for (idx, layer) in grads.layers.iter().enumerate() {
                frames.push(self.encode_layer(idx, layer)?);
            }
            return Ok(frames);
        }
        let use_ctrl = self.cfg.autotune && !self.cfg.full_batch;
        let cfg = &self.cfg;
        let mut ctrl_iter = if use_ctrl { Some(self.tau_ctrl.iter_mut()) } else { None };
        let items: Vec<(&LayerGrad, &mut LayerState, Option<&mut TauController>)> = grads
            .layers
            .iter()
            .zip(self.state.layers.iter_mut())
            .map(|(layer, st)| {
                let ctrl = ctrl_iter.as_mut().and_then(|it| it.next());
                (layer, st, ctrl)
            })
            .collect();
        let results = crate::util::threadpool::parallel_map(items, threads, |(layer, st, ctrl)| {
            compress_layer_impl(cfg, layer, st, ctrl, None)
        });
        let mut frames = Vec::with_capacity(n);
        for (idx, res) in results.into_iter().enumerate() {
            let (payload, report) = res?;
            frames.push(Frame::new(idx, payload, report));
        }
        Ok(frames)
    }

    fn name(&self) -> &'static str {
        "fedgec"
    }

    fn reset(&mut self) {
        self.state.reset();
        self.tau_ctrl.clear();
    }

    fn state_fingerprint(&self) -> u64 {
        self.state.fingerprint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::LayerMeta;
    use crate::util::rng::Rng;

    fn make_grads(rng: &mut Rng, scale: f32) -> ModelGrad {
        // One conv layer with dominant-sign kernels + one dense + one bias.
        let t = 9;
        let n_kernels = 128;
        let mut conv = Vec::with_capacity(n_kernels * t);
        for _ in 0..n_kernels {
            let dom: f32 = if rng.chance(0.5) { 1.0 } else { -1.0 };
            for _ in 0..t {
                let flip = rng.chance(0.12);
                conv.push(dom * if flip { -1.0 } else { 1.0 } * (0.2 + rng.next_f32()) * scale);
            }
        }
        let dense: Vec<f32> = (0..2048).map(|_| rng.normal_f32(0.0, scale)).collect();
        let bias: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, scale)).collect();
        ModelGrad {
            layers: vec![
                LayerGrad::new(LayerMeta::conv("conv", n_kernels, 1, 3, 3), conv),
                LayerGrad::new(LayerMeta::dense("dense", 32, 64), dense),
                LayerGrad::new(LayerMeta::other("bias", 16), bias),
            ],
        }
    }

    fn metas(g: &ModelGrad) -> Vec<LayerMeta> {
        g.layers.iter().map(|l| l.meta.clone()).collect()
    }

    #[test]
    fn roundtrip_respects_error_bound_over_rounds() {
        let mut rng = Rng::new(1);
        let mut client = FedgecCodec::new(FedgecConfig::default());
        let mut server = FedgecCodec::new(FedgecConfig::default());
        for round in 0..5 {
            let scale = 1.0 / (1.0 + round as f32 * 0.3);
            let grads = make_grads(&mut rng, scale);
            let payload = client.compress(&grads).unwrap();
            let recon = server.decompress(&payload, &metas(&grads)).unwrap();
            // Bias layer (small) must be exact.
            assert_eq!(recon.layers[2].data, grads.layers[2].data);
            // Lossy layers within REL bound.
            for li in 0..2 {
                let (lo, hi) = stats::finite_min_max(&grads.layers[li].data);
                let delta = FedgecConfig::default().error_bound.resolve(lo, hi) as f32;
                for (r, g) in recon.layers[li].data.iter().zip(&grads.layers[li].data) {
                    assert!((r - g).abs() <= delta * 1.0001, "round {round} layer {li}");
                }
            }
            // Client/server states stay synchronized.
            assert_eq!(client.state.fingerprint(), server.state.fingerprint());
        }
    }

    #[test]
    fn compresses_structured_gradients_well() {
        let mut rng = Rng::new(2);
        let mut client = FedgecCodec::new(FedgecConfig {
            error_bound: ErrorBound::Rel(3e-2),
            ..Default::default()
        });
        // Warm up two rounds so the predictor has history.
        let mut ratio = 0.0;
        for _ in 0..4 {
            let grads = make_grads(&mut rng, 1.0);
            let payload = client.compress(&grads).unwrap();
            ratio = grads.byte_size() as f64 / payload.len() as f64;
        }
        assert!(ratio > 4.0, "expected CR > 4, got {ratio:.2}");
    }

    #[test]
    fn frame_api_matches_whole_model_adapter() {
        // Session encoding must produce byte-identical frames to the
        // blanket adapter (same per-layer state evolution).
        let mut rng = Rng::new(11);
        let g = make_grads(&mut rng, 1.0);
        let mut a = FedgecCodec::new(FedgecConfig::default());
        let mut b = FedgecCodec::new(FedgecConfig::default());
        let payload = a.compress(&g).unwrap();
        b.begin(g.layers.len()).unwrap();
        let mut frames = Vec::new();
        for (idx, layer) in g.layers.iter().enumerate() {
            frames.push(b.encode_layer(idx, layer).unwrap());
        }
        assert_eq!(payload, crate::compress::frame::frames_to_payload(&frames));
    }

    #[test]
    fn parallel_encode_matches_sequential() {
        // A model large enough to trip the parallel path must produce the
        // exact payload of per-layer sequential encoding.
        let mut rng = Rng::new(12);
        let n = 40_000; // 4 layers x 40k elements > PARALLEL_MIN_NUMEL
        let layers: Vec<LayerGrad> = (0..4)
            .map(|i| {
                let data: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.5)).collect();
                LayerGrad::new(LayerMeta::dense(&format!("fc{i}"), n, 1), data)
            })
            .collect();
        let g = ModelGrad { layers };
        let mut par = FedgecCodec::new(FedgecConfig::default());
        let mut seq = FedgecCodec::new(FedgecConfig::default());
        for _ in 0..3 {
            let frames_par = par.encode_model(&g).unwrap();
            seq.begin(g.layers.len()).unwrap();
            let frames_seq: Vec<_> = g
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| seq.encode_layer(i, l).unwrap())
                .collect();
            assert_eq!(frames_par.len(), frames_seq.len());
            for (a, b) in frames_par.iter().zip(&frames_seq) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.payload, b.payload);
            }
            assert_eq!(par.state.fingerprint(), seq.state.fingerprint());
        }
    }

    #[test]
    fn reports_flow_through_trait() {
        let mut rng = Rng::new(13);
        let g = make_grads(&mut rng, 1.0);
        let mut client = FedgecCodec::new(FedgecConfig::default());
        let mut server = FedgecCodec::new(FedgecConfig::default());
        let (payload, creport) = client.compress_with_report(&g).unwrap();
        assert_eq!(creport.layers.len(), 3);
        assert_eq!(creport.total_raw(), g.byte_size());
        assert!(creport.layers[0].lossy && !creport.layers[2].lossy);
        // Wire accounting matches the actual payload (count header + frames).
        assert_eq!(creport.total_compressed() + 4, payload.len());
        let (_, sreport) = server.decompress_with_report(&payload, &metas(&g)).unwrap();
        assert_eq!(sreport.total_raw(), creport.total_raw());
        assert_eq!(sreport.total_compressed(), creport.total_compressed());
    }

    #[test]
    fn reset_clears_state() {
        let mut rng = Rng::new(3);
        let mut c = FedgecCodec::new(FedgecConfig::default());
        let g = make_grads(&mut rng, 1.0);
        c.compress(&g).unwrap();
        assert!(c.state.layers.iter().any(|l| l.prev_recon.is_some()));
        c.reset();
        assert!(c.state.layers.iter().all(|l| l.prev_recon.is_none()));
    }

    #[test]
    fn wrong_meta_count_errors() {
        let mut rng = Rng::new(4);
        let mut c = FedgecCodec::new(FedgecConfig::default());
        let g = make_grads(&mut rng, 1.0);
        let payload = c.compress(&g).unwrap();
        let mut s = FedgecCodec::new(FedgecConfig::default());
        let bad_metas = &metas(&g)[..2];
        assert!(s.decompress(&payload, bad_metas).is_err());
    }

    #[test]
    fn full_batch_mode_roundtrips() {
        let mut rng = Rng::new(5);
        let cfg = FedgecConfig { full_batch: true, ..Default::default() };
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg);
        let base = make_grads(&mut rng, 1.0);
        for round in 0..4 {
            // Oscillating gradients: alternate global sign.
            let mut g = base.clone();
            let flip = if round % 2 == 0 { 1.0f32 } else { -1.0 };
            for l in &mut g.layers {
                for v in &mut l.data {
                    *v *= flip * (1.0 + 0.05 * rng.gauss() as f32);
                }
            }
            let payload = client.compress(&g).unwrap();
            let recon = server.decompress(&payload, &metas(&g)).unwrap();
            assert_eq!(client.state.fingerprint(), server.state.fingerprint());
            let _ = recon;
        }
    }

    #[test]
    fn autotune_stays_synchronized_and_bounded() {
        let mut rng = Rng::new(21);
        let cfg = FedgecConfig { autotune: true, ..Default::default() };
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg);
        for round in 0..6 {
            let grads = make_grads(&mut rng, 1.0 / (1.0 + round as f32 * 0.2));
            let payload = client.compress(&grads).unwrap();
            let recon = server.decompress(&payload, &metas(&grads)).unwrap();
            // Error bound still holds under auto-tuned parameters.
            for li in 0..2 {
                let (lo, hi) = stats::finite_min_max(&grads.layers[li].data);
                let delta = FedgecConfig::default().error_bound.resolve(lo, hi) as f32;
                for (r, g) in recon.layers[li].data.iter().zip(&grads.layers[li].data) {
                    assert!((r - g).abs() <= delta * 1.0001, "round {round}");
                }
            }
            assert_eq!(
                client.state.fingerprint(),
                server.state.fingerprint(),
                "autotune broke sync at round {round}"
            );
        }
        // The controller actually moved (or at least exists) per layer.
        assert!(!client.tau_ctrl.is_empty());
        for c in &client.tau_ctrl {
            assert!((c.min_tau..=c.max_tau).contains(&c.tau));
        }
    }

    #[test]
    fn rans_pipeline_roundtrips_and_pins_v2_header() {
        let mut rng = Rng::new(31);
        let cfg = FedgecConfig {
            entropy: EntropyCoder::Rans,
            backend: Backend::None,
            ..Default::default()
        };
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg);
        for round in 0..3 {
            let grads = make_grads(&mut rng, 1.0);
            let payload = client.compress(&grads).unwrap();
            // Golden blob header: lossy sections open with the v2 tag and
            // the recorded rANS coder tag.
            let frames = crate::compress::frame::payload_to_frames(&payload).unwrap();
            let section = lossless::decompress(&frames[0].payload).unwrap();
            assert_eq!(section[0], crate::compress::blob::SECTION_LOSSY_V2, "round {round}");
            assert_eq!(section[1], EntropyCoder::Rans.tag(), "round {round}");
            let recon = server.decompress(&payload, &metas(&grads)).unwrap();
            for li in 0..2 {
                let (lo, hi) = stats::finite_min_max(&grads.layers[li].data);
                let delta = FedgecConfig::default().error_bound.resolve(lo, hi) as f32;
                for (r, g) in recon.layers[li].data.iter().zip(&grads.layers[li].data) {
                    assert!((r - g).abs() <= delta * 1.0001, "round {round} layer {li}");
                }
            }
            assert_eq!(client.state.fingerprint(), server.state.fingerprint());
        }
    }

    #[test]
    fn rans_and_huffman_pipelines_reconstruct_identically() {
        // The entropy stage is lossless, so the two coders must yield
        // bit-identical reconstructions and predictor states.
        let mut rng = Rng::new(32);
        let mut huff = FedgecCodec::new(FedgecConfig::default());
        let mut rans = FedgecCodec::new(FedgecConfig {
            entropy: EntropyCoder::Rans,
            ..Default::default()
        });
        let mut huff_srv = FedgecCodec::new(FedgecConfig::default());
        let mut rans_srv = FedgecCodec::new(FedgecConfig {
            entropy: EntropyCoder::Rans,
            ..Default::default()
        });
        for _ in 0..3 {
            let grads = make_grads(&mut rng, 1.0);
            let ph = huff.compress(&grads).unwrap();
            let pr = rans.compress(&grads).unwrap();
            let rh = huff_srv.decompress(&ph, &metas(&grads)).unwrap();
            let rr = rans_srv.decompress(&pr, &metas(&grads)).unwrap();
            for (a, b) in rh.layers.iter().zip(&rr.layers) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            assert_eq!(huff.state.fingerprint(), rans.state.fingerprint());
        }
    }

    #[test]
    fn engine_decode_matches_mirrored_codec() {
        // The stateless engine + external state must reproduce the old
        // one-mirror-per-client decode bit for bit, including the state
        // evolution across rounds.
        use crate::compress::engine::CodecEngine;
        let mut rng = Rng::new(41);
        let mut client = FedgecCodec::new(FedgecConfig::default());
        let mut mirror = FedgecCodec::new(FedgecConfig::default());
        let mut engine = FedgecEngine::new(FedgecConfig::default());
        let mut state = CodecState::default();
        assert!(engine.stateful());
        for round in 0..4 {
            let grads = make_grads(&mut rng, 1.0 / (1.0 + round as f32 * 0.25));
            let payload = client.compress(&grads).unwrap();
            let via_mirror = mirror.decompress(&payload, &metas(&grads)).unwrap();
            let (via_engine, report) =
                engine.decode_payload(&payload, &metas(&grads), &mut state).unwrap();
            for (a, b) in via_mirror.layers.iter().zip(&via_engine.layers) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "round {round}");
                }
            }
            assert_eq!(report.layers.len(), grads.layers.len());
            assert_eq!(state.fingerprint(), mirror.state.fingerprint(), "round {round}");
            assert_eq!(state.fingerprint(), client.state_fingerprint(), "round {round}");
        }
    }

    #[test]
    fn corrupted_payload_errors_not_panics() {
        let mut rng = Rng::new(6);
        let mut c = FedgecCodec::new(FedgecConfig::default());
        let g = make_grads(&mut rng, 1.0);
        let mut payload = c.compress(&g).unwrap();
        let mid = payload.len() / 2;
        payload[mid] ^= 0xFF;
        let mut s = FedgecCodec::new(FedgecConfig::default());
        let _ = s.decompress(&payload, &metas(&g)); // any Err is fine; must not panic
        let _ = s.decompress(&payload[..10], &metas(&g));
    }
}
