//! The complete FedGEC codec — paper Algorithms 3 (client) and 4 (server).
//!
//! Per layer: small tensors (`numel ≤ T_LOSSY`) are stored losslessly;
//! large tensors run the four-stage lossy pipeline with the gradient-aware
//! predictor. The per-layer frame bundles `(μ_curr, σ_curr)`, the sign
//! side-info (flip bit or two-level bitmap), the Huffman-coded residual
//! codes and the escape values, and is closed by the lossless backend —
//! exactly the structure of Alg. 3 lines 6-16.
//!
//! The codec speaks the session/frame API: each layer's state is
//! independent, so [`FedgecCodec::encode_model`] compresses layers in
//! parallel on [`crate::util::threadpool`] for large models (the layer
//! pipeline the paper's deployment story needs).
//!
//! The predict stage is pluggable twice over:
//!
//! * **Which predictor runs** is selected by
//!   [`FedgecConfig::predictor`] (spec keys `pred=`/`sign=`, see
//!   [`crate::compress::predictor`]): the implicit config-driven EMA
//!   keeps the seed-byte-compatible v1/v2 frames, while
//!   `pred=last|zero|auto` writes self-describing v3 sections that
//!   record the predictor tag actually used — `pred=auto` races the
//!   fixed predictors per layer each round by exact measured bytes.
//! * **Where the EMA math executes**: the native fused path
//!   ([`crate::compress::fused`]) or a pluggable [`PredictBackend`]
//!   (the PJRT/HLO engine in `crate::runtime` that executes the Pallas
//!   kernel's lowering — EMA only).

use super::autotune::TauController;
use super::blob::{
    bytes_to_f32s, f32s_to_bytes, put_coder_suffix, put_pred_header, read_pred_suffix,
    read_section_coder, section_tag_for, BlobReader, BlobWriter, SECTION_LOSSLESS,
    SECTION_LOSSY_V3,
};
use super::entropy::EntropyCoder;
use super::frame::Frame;
use super::fused::{fused_decode, fused_encode, FusedEncodeOut, FusedParams};
use super::lossless::{self, Backend};
use super::predictor::magnitude::{
    absorb_with_tag, predict_with_tag, MagnitudeSel, PredTag, DEFAULT_BETA,
};
use super::predictor::sign::{
    reconstruct_signs, KernelSign, NoSign, OscSign, SignMeta, SignPredictor, SignSel,
};
use super::control::EbPlan;
use super::predictor::PredictorSpec;
use super::quant::{self, ErrorBound, Quantized};
use super::state::{CodecState, LayerState};
use super::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};
use crate::util::stats;

pub use super::frame::LayerReport;

/// Tunable knobs of the codec (paper Alg. 3 parameter list).
#[derive(Debug, Clone)]
pub struct FedgecConfig {
    /// EMA decay factor β (paper α in Alg. 3; default 0.9).
    pub beta: f32,
    /// Kernel sign-consistency threshold τ (default 0.5, §5.4).
    pub tau: f64,
    /// Full-batch GD flag: oscillation sign mode instead of kernel mode.
    pub full_batch: bool,
    /// Error bound (ABS or REL).
    pub error_bound: ErrorBound,
    /// Layers with `numel ≤ t_lossy` are stored losslessly (Alg. 3 line 3).
    pub t_lossy: usize,
    /// Stage-3 entropy coder (spec key `ec`; Huffman keeps the seed's
    /// byte-compatible v1 sections, anything else writes v2).
    pub entropy: EntropyCoder,
    /// Stage-4 lossless backend.
    pub backend: Backend,
    /// Auto-tune τ (client-side controller) and β (deterministic
    /// history-derived schedule) — the paper's §6 extension. See
    /// [`super::autotune`].
    pub autotune: bool,
    /// Predict-stage selection (spec keys `pred=` / `sign=`): which
    /// magnitude predictor and which sign policy run. `pred=ema` keeps
    /// the seed-byte-compatible implicit frames; `last`/`zero`/`auto`
    /// write self-describing v3 layer sections that record the predictor
    /// tag actually used (the `auto` race records its per-round winner).
    pub predictor: PredictorSpec,
}

impl Default for FedgecConfig {
    fn default() -> Self {
        FedgecConfig {
            beta: DEFAULT_BETA,
            tau: 0.5,
            full_batch: false,
            error_bound: ErrorBound::Rel(1e-2),
            t_lossy: 1024,
            entropy: EntropyCoder::Huffman,
            backend: Backend::default(),
            autotune: false,
            predictor: PredictorSpec::default(),
        }
    }
}

/// Whether the client-side τ controllers are live: they exist for the
/// kernel sign policy only (the oscillation flip and the off policy
/// have no τ).
fn use_tau_ctrl(cfg: &FedgecConfig) -> bool {
    cfg.autotune && cfg.predictor.sign.effective(cfg.full_batch) == SignSel::Kernel
}

/// Whether this configuration never reads or writes cross-round
/// predictor state: `pred=zero` predicts all-zero without history, an
/// effective `sign=none` reconstructs zero signs without history (so
/// ĝ = S ⊙ â ≡ 0 on every path), and `autotune` is off (its β schedule
/// is history-derived). Under this mode both sides skip the state
/// absorb entirely — mirrors stay cold and bit-identical by
/// construction — and the server can aggregate frames in the integer
/// bin domain (see [`crate::compress::agg`]): `recon = 2Δ·code` plus
/// exact escapes, with no per-client reconstruction pass.
pub fn state_free_mode(cfg: &FedgecConfig) -> bool {
    cfg.predictor.mag == MagnitudeSel::Zero
        && cfg.predictor.sign.effective(cfg.full_batch) == SignSel::None
        && !cfg.autotune
}

/// The config a layer actually encodes/decodes under: the base config
/// with the active round's [`EbPlan`] (if any) substituted into
/// `error_bound`. Borrows when no plan is active, so the `ebc=fixed`
/// path pays nothing. The plan carries magnitudes only —
/// [`EbPlan::bound_for`] preserves the base bound's ABS/REL mode, so a
/// plan can never flip a binsum-eligible spec out of eligibility.
fn effective_cfg<'a>(
    cfg: &'a FedgecConfig,
    plan: Option<&EbPlan>,
    idx: usize,
) -> std::borrow::Cow<'a, FedgecConfig> {
    match plan {
        None => std::borrow::Cow::Borrowed(cfg),
        Some(p) => {
            let mut c = cfg.clone();
            c.error_bound = p.bound_for(cfg.error_bound, idx);
            std::borrow::Cow::Owned(c)
        }
    }
}

/// Reusable per-layer-slot scratch: sign/prediction buffers, quantizer
/// outputs and the `pred=auto` race double-buffers all survive across
/// rounds, so the per-round hot path stops allocating after warm-up
/// (the predictor-API satellite of the encode/decode rewrite).
#[derive(Default)]
pub struct LayerScratch {
    signs: Vec<f32>,
    out: FusedEncodeOut,
    ghat: Vec<f32>,
    cand_q: Quantized,
    cand_recon: Vec<f32>,
    cand_mem: Vec<f32>,
    best_mem: Vec<f32>,
}

/// Pluggable predict-stage engine (see module docs). `memory` is updated
/// in place; returns `ĝ = S ⊙ â`.
pub trait PredictBackend: Send {
    fn predict(
        &mut self,
        prev_abs: &[f32],
        memory: &mut [f32],
        signs: &[f32],
        p: &FusedParams,
    ) -> anyhow::Result<Vec<f32>>;
}

/// The FedGEC codec: symmetric client/server object implementing
/// [`GradientCodec`].
pub struct FedgecCodec {
    pub cfg: FedgecConfig,
    pub state: CodecState,
    /// Optional PJRT/HLO predict engine; `None` ⇒ native fused path.
    pub engine: Option<Box<dyn PredictBackend>>,
    /// Per-layer τ controllers (client side, active when cfg.autotune).
    pub tau_ctrl: Vec<TauController>,
    /// The server-broadcast error-bound plan for the current round
    /// (`ebc=` controllers, DESIGN.md §15). Round-scoped *config*, not
    /// state: it survives `reset()` — a resynced client keeps the
    /// current round's broadcast plan, not its pre-dropout bound.
    pub plan: Option<EbPlan>,
    /// Per-layer-slot reusable scratch (not state: never fingerprinted,
    /// never mirrored, never stored).
    scratch: Vec<LayerScratch>,
}

impl FedgecCodec {
    pub fn new(cfg: FedgecConfig) -> Self {
        FedgecCodec {
            cfg,
            state: CodecState::default(),
            engine: None,
            tau_ctrl: Vec::new(),
            plan: None,
            scratch: Vec::new(),
        }
    }

    pub fn with_engine(cfg: FedgecConfig, engine: Box<dyn PredictBackend>) -> Self {
        FedgecCodec {
            cfg,
            state: CodecState::default(),
            engine: Some(engine),
            tau_ctrl: Vec::new(),
            plan: None,
            scratch: Vec::new(),
        }
    }

    fn ensure_ctrl(&mut self, n: usize) {
        if use_tau_ctrl(&self.cfg) {
            while self.tau_ctrl.len() < n {
                self.tau_ctrl.push(TauController { tau: self.cfg.tau, ..Default::default() });
            }
        }
    }

    fn ensure_scratch(&mut self, n: usize) {
        while self.scratch.len() < n {
            self.scratch.push(LayerScratch::default());
        }
    }

    /// Worker count for layer-parallel encoding (1 = stay sequential; the
    /// HLO engine path is inherently sequential).
    fn encode_threads(&self, grads: &ModelGrad) -> usize {
        if self.engine.is_some() {
            return 1;
        }
        crate::util::threadpool::layer_parallelism(grads.layers.len(), grads.numel())
    }
}

/// The effective β for a layer this round: config value, or the
/// deterministic history-derived schedule when auto-tuning (identical on
/// both sides — derived from reconstructed data only).
fn effective_beta(cfg: &FedgecConfig, st: &LayerState) -> f32 {
    if !cfg.autotune {
        return cfg.beta;
    }
    match (&st.prev_abs, &st.prev_prev_abs) {
        (Some(a), Some(b)) => super::autotune::beta_from_history(a, b),
        _ => cfg.beta,
    }
}

/// The extra v3 header bytes a race candidate pays on the wire: one
/// predictor tag byte (shared by all candidates, so excluded) plus the
/// 4-byte β only the EMA winner records.
fn pred_header_extra(tag: PredTag) -> usize {
    if tag == PredTag::Ema {
        4
    } else {
        0
    }
}

/// Race the fixed magnitude predictors for one layer from the **same**
/// state and pick the cheapest by exact measured bytes (entropy stream
/// via [`super::autotune::entropy_stage_cost`] + escaped values + the
/// predictor-header differential). The winner's codes/escapes/recon end
/// up in `scratch.out` (and its updated EMA memory in
/// `scratch.best_mem`, committed by the caller only when EMA wins — the
/// frame-driven memory-update rule the decoder mirrors). Ties keep the
/// earlier candidate of the fixed `[zero, last, ema]` order, so the
/// choice is deterministic.
fn race_predictors(
    grad: &[f32],
    beta: f32,
    st: &LayerState,
    mu_curr: f32,
    sigma_curr: f32,
    delta: f64,
    scratch: &mut LayerScratch,
) -> crate::Result<(PredTag, Vec<(String, usize)>)> {
    let n = grad.len();
    let prev_abs = st.prev_abs.as_deref();
    let mut log = Vec::with_capacity(3);
    let mut best: Option<(PredTag, usize)> = None;
    for tag in [PredTag::Zero, PredTag::Last, PredTag::Ema] {
        scratch.cand_mem.clear();
        if tag == PredTag::Ema {
            scratch.cand_mem.extend_from_slice(&st.memory);
        }
        predict_with_tag(
            tag,
            beta,
            prev_abs,
            &mut scratch.cand_mem,
            mu_curr,
            sigma_curr,
            n,
            &mut scratch.ghat,
        )?;
        for (g, &s) in scratch.ghat.iter_mut().zip(scratch.signs.iter()) {
            *g *= s;
        }
        quant::quantize(grad, &scratch.ghat, delta, &mut scratch.cand_q, &mut scratch.cand_recon);
        let hist = quant::code_histogram(&scratch.cand_q.codes);
        let cost = super::autotune::entropy_stage_cost(&hist, scratch.cand_q.codes.len())
            + 4 * scratch.cand_q.escapes.len()
            + pred_header_extra(tag);
        log.push((tag.name().to_string(), cost));
        if best.map_or(true, |(_, c)| cost < c) {
            best = Some((tag, cost));
            std::mem::swap(&mut scratch.out.codes, &mut scratch.cand_q.codes);
            std::mem::swap(&mut scratch.out.escapes, &mut scratch.cand_q.escapes);
            std::mem::swap(&mut scratch.out.recon, &mut scratch.cand_recon);
            std::mem::swap(&mut scratch.best_mem, &mut scratch.cand_mem);
        }
    }
    Ok((best.expect("three candidates raced").0, log))
}

/// Compress one layer into its closed (post-lossless) frame payload.
/// Free-standing over the layer's own state so layers encode in parallel.
fn compress_layer_impl(
    cfg: &FedgecConfig,
    layer: &LayerGrad,
    st: &mut LayerState,
    ctrl: Option<&mut TauController>,
    scratch: &mut LayerScratch,
    engine: Option<&mut dyn PredictBackend>,
) -> crate::Result<(Vec<u8>, LayerReport)> {
    let grad = &layer.data;
    let n = grad.len();
    let mut report = LayerReport {
        name: layer.meta.name.clone(),
        raw_bytes: n * 4,
        ..Default::default()
    };
    let mut w = BlobWriter::new();

    if n <= cfg.t_lossy {
        // Alg. 3 line 3-4: lossless-only small layer (bypasses predictor
        // state entirely).
        w.put_u8(SECTION_LOSSLESS);
        w.put_bytes(&f32s_to_bytes(grad));
        let closed = cfg.backend.compress(&w.into_bytes())?;
        return Ok((closed, report));
    }
    report.lossy = true;

    // --- Stage 1a: sign prediction (Alg. 3 line 10), behind the
    // SignPredictor API. The side info self-describes, so the policy
    // needs no frame-header support of its own. ---
    let tau = ctrl.as_ref().map(|c| c.tau).unwrap_or(cfg.tau);
    let kernel;
    let sign_pred: &dyn SignPredictor = match cfg.predictor.sign.effective(cfg.full_batch) {
        SignSel::Osc => &OscSign,
        SignSel::None => &NoSign,
        SignSel::Kernel | SignSel::Auto => {
            kernel = KernelSign { tau };
            &kernel
        }
    };
    let beta = effective_beta(cfg, st);
    let (sign_meta, sign_stats) =
        sign_pred.predict_into(grad, &layer.meta.kind, st, &mut scratch.signs);
    report.sign_stats = sign_stats;
    if let Some(ctrl) = ctrl {
        if sign_stats.kernels_total > 0 {
            ctrl.update(sign_stats.mismatch_rate(), sign_stats.prediction_ratio());
        }
    }
    let signs = &scratch.signs;

    // --- Stage 1b+2: magnitude prediction + quantization. ---
    let (mu_curr, sigma_curr) = stats::mean_std_abs(grad);
    let (lo, hi) = stats::finite_min_max(grad);
    let delta = cfg.error_bound.resolve(lo, hi);

    // `None` ⇒ the implicit config-driven EMA (seed-byte-compatible
    // v1/v2 sections); `Some(tag)` ⇒ a self-describing v3 section
    // recording the predictor actually used.
    let wire_pred: Option<PredTag>;
    let mut race_log = Vec::new();
    match cfg.predictor.mag {
        MagnitudeSel::Ema => {
            wire_pred = None;
            let empty: [f32; 0] = [];
            let prev_abs: &[f32] = st.prev_abs.as_deref().unwrap_or(&empty);
            let (mu_prev, sigma_prev) = stats::mean_std(prev_abs);
            let p = FusedParams {
                beta,
                mu_curr,
                sigma_curr,
                mu_prev,
                sigma_prev,
                two_delta: (2.0 * delta) as f32,
                delta: delta as f32,
            };
            match engine {
                None => {
                    fused_encode(grad, prev_abs, &mut st.memory, signs, &p, &mut scratch.out);
                }
                Some(engine) => {
                    if !prev_abs.is_empty() && st.memory.len() != n {
                        st.memory.clear();
                        st.memory.resize(n, 0.0);
                    }
                    let ghat = if prev_abs.is_empty() {
                        vec![0.0; n]
                    } else {
                        engine.predict(prev_abs, &mut st.memory, signs, &p)?
                    };
                    quant::quantize(grad, &ghat, delta, &mut scratch.cand_q, &mut scratch.out.recon);
                    std::mem::swap(&mut scratch.out.codes, &mut scratch.cand_q.codes);
                    std::mem::swap(&mut scratch.out.escapes, &mut scratch.cand_q.escapes);
                }
            }
        }
        MagnitudeSel::Last | MagnitudeSel::Zero => {
            // Fixed non-EMA predictor through the MagnitudePredictor API
            // (the PJRT/HLO backend implements the EMA kernel only, so
            // this path is always native).
            let tag = if cfg.predictor.mag == MagnitudeSel::Last {
                PredTag::Last
            } else {
                PredTag::Zero
            };
            let prev_abs = st.prev_abs.as_deref();
            predict_with_tag(
                tag,
                beta,
                prev_abs,
                &mut st.memory,
                mu_curr,
                sigma_curr,
                n,
                &mut scratch.ghat,
            )?;
            for (g, &s) in scratch.ghat.iter_mut().zip(signs) {
                *g *= s;
            }
            quant::quantize(grad, &scratch.ghat, delta, &mut scratch.cand_q, &mut scratch.out.recon);
            std::mem::swap(&mut scratch.out.codes, &mut scratch.cand_q.codes);
            std::mem::swap(&mut scratch.out.escapes, &mut scratch.cand_q.escapes);
            wire_pred = Some(tag);
        }
        MagnitudeSel::Auto => {
            // The race reads the sign buffer out of the same scratch.
            let (tag, log) =
                race_predictors(grad, beta, st, mu_curr, sigma_curr, delta, scratch)?;
            race_log = log;
            if tag == PredTag::Ema {
                // Frame-driven memory rule: EMA memory advances exactly
                // on the rounds whose frame records the EMA tag.
                std::mem::swap(&mut st.memory, &mut scratch.best_mem);
            }
            wire_pred = Some(tag);
        }
    }
    let out = &mut scratch.out;
    report.escape_count = out.escapes.len();

    // --- Stage 3: entropy coding. ---
    // The coder is a client-only decision (recorded in the section
    // header), so autotune may pick the cheaper one per layer with zero
    // synchronization cost. One histogram pass feeds both the choice and
    // the chosen encoder (§Perf: the code stream is layer-sized).
    let (coder, entropy) = if cfg.autotune && cfg.entropy != EntropyCoder::Raw {
        let hist = quant::code_histogram(&out.codes);
        let coder =
            super::autotune::pick_entropy_coder_from_hist(&hist, out.codes.len(), cfg.entropy);
        let entropy = coder.encode_to_bytes_with_hist(&out.codes, &hist);
        (coder, entropy)
    } else {
        let coder = cfg.entropy;
        (coder, coder.encode_to_bytes(&out.codes))
    };
    report.entropy_bytes = entropy.len();
    report.entropy_coder = coder.name().to_string();
    let sign_bytes = sign_meta.encode();
    report.side_info_bytes = sign_bytes.len() + out.escapes.len() * 4;

    // --- Layer section (Alg. 3 line 15; Huffman + implicit EMA keeps
    // the seed's v1 bytes; explicit predictors self-describe in v3). ---
    match wire_pred {
        None => {
            w.put_u8(section_tag_for(coder));
            put_coder_suffix(&mut w, coder);
            report.pred_tag = PredTag::Ema.name().to_string();
        }
        Some(tag) => {
            put_pred_header(&mut w, coder, tag, beta);
            report.pred_tag = tag.name().to_string();
        }
    }
    report.pred_race = race_log;
    w.put_u32(n as u32);
    w.put_f32(mu_curr);
    w.put_f32(sigma_curr);
    w.put_f64(delta);
    w.put_bytes(&sign_bytes);
    w.put_bytes(&entropy);
    w.put_f32_slice(&out.escapes);

    // Update local state with the reconstruction (client mirror); the
    // selector tag rides along into the fingerprint. Explicit
    // predictors absorb through their trait impl; the implicit path is
    // the hand-fused EMA specialization of the same shared absorb.
    // State-free configurations never read the mirror back, so both
    // sides skip the absorb and stay cold (fingerprint-identical).
    if !state_free_mode(cfg) {
        st.pred = cfg.predictor.mag.state_tag();
        st.eb = cfg.error_bound.state_bits();
        match wire_pred {
            None => st.absorb(&out.recon),
            Some(tag) => absorb_with_tag(tag, beta, st, &out.recon),
        }
    }
    let closed = cfg.backend.compress(&w.into_bytes())?;
    Ok((closed, report))
}

/// Decode one layer's frame section (post-lossless bytes).
fn decompress_layer_impl(
    cfg: &FedgecConfig,
    meta: &LayerMeta,
    section: &[u8],
    st: &mut LayerState,
    scratch: &mut LayerScratch,
    engine: Option<&mut dyn PredictBackend>,
) -> crate::Result<(Vec<f32>, LayerReport)> {
    let mut r = BlobReader::new(section);
    let tag = r.get_u8()?;
    let mut report = LayerReport { name: meta.name.clone(), ..Default::default() };
    if tag == SECTION_LOSSLESS {
        let data = bytes_to_f32s(r.get_bytes()?)?;
        anyhow::ensure!(data.len() == meta.numel, "layer {}: lossless numel", meta.name);
        report.raw_bytes = data.len() * 4;
        return Ok((data, report));
    }
    // Dispatch on the recorded coder: v1 sections are implicitly Huffman,
    // v2/v3 sections carry the coder tag; v3 additionally records the
    // magnitude predictor that produced the frame (+ the EMA β), so the
    // reconstruction needs zero out-of-band predictor configuration.
    let coder = read_section_coder(&mut r, tag)
        .map_err(|e| anyhow::anyhow!("layer {}: {e}", meta.name))?;
    let wire_pred = if tag == SECTION_LOSSY_V3 {
        Some(read_pred_suffix(&mut r).map_err(|e| anyhow::anyhow!("layer {}: {e}", meta.name))?)
    } else {
        None
    };
    report.lossy = true;
    report.entropy_coder = coder.name().to_string();
    let n = r.get_u32()? as usize;
    if n != meta.numel {
        anyhow::bail!("layer {}: payload numel {} != meta {}", meta.name, n, meta.numel);
    }
    report.raw_bytes = n * 4;
    let mu_curr = r.get_f32()?;
    let sigma_curr = r.get_f32()?;
    let delta = r.get_f64()?;
    let sign_bytes = r.get_bytes()?;
    // `n` is validated against the trusted meta, so it bounds both the
    // sign side info and the entropy decode (a corrupt stream cannot
    // declare inflated bitmap/symbol counts).
    let sign_meta = SignMeta::decode_bounded(sign_bytes, n)?;
    let entropy = r.get_bytes()?;
    report.entropy_bytes = entropy.len();
    let (codes, _) = coder.decode_bounded(entropy, n)?;
    if codes.len() != n {
        anyhow::bail!("layer {}: {} codes for {} elements", meta.name, codes.len(), n);
    }
    let escapes = r.get_f32_vec()?;
    report.side_info_bytes = sign_bytes.len() + escapes.len() * 4;
    report.escape_count = escapes.len();

    let signs = reconstruct_signs(&sign_meta, n, &meta.kind, st.prev_sign.as_deref())?;
    let mut recon = Vec::new();
    match wire_pred {
        None => {
            // Implicit config-driven EMA (v1/v2): the classic fused path.
            let beta = effective_beta(cfg, st);
            let empty: [f32; 0] = [];
            let prev_abs: &[f32] = st.prev_abs.as_deref().unwrap_or(&empty);
            let (mu_prev, sigma_prev) = stats::mean_std(prev_abs);
            let p = FusedParams {
                beta,
                mu_curr,
                sigma_curr,
                mu_prev,
                sigma_prev,
                two_delta: (2.0 * delta) as f32,
                delta: delta as f32,
            };
            match engine {
                None => {
                    fused_decode(
                        &codes,
                        &escapes,
                        prev_abs,
                        &mut st.memory,
                        &signs,
                        &p,
                        &mut recon,
                    )?;
                }
                Some(engine) => {
                    if !prev_abs.is_empty() && st.memory.len() != n {
                        st.memory.clear();
                        st.memory.resize(n, 0.0);
                    }
                    let ghat = if prev_abs.is_empty() {
                        vec![0.0; n]
                    } else {
                        engine.predict(prev_abs, &mut st.memory, &signs, &p)?
                    };
                    let q = Quantized { codes, escapes };
                    quant::dequantize_checked(&q, &ghat, delta, &mut recon)?;
                }
            }
            report.pred_tag = PredTag::Ema.name().to_string();
        }
        Some((ptag, wire_beta)) => {
            // Self-describing frame: reconstruct with the recorded
            // predictor + β. The EMA memory advances exactly on the
            // rounds whose frame carries the EMA tag, mirroring the
            // encoder's frame-driven rule (under `pred=auto` both sides
            // therefore stay bit-identical without knowing the race).
            let prev_abs = st.prev_abs.as_deref();
            predict_with_tag(
                ptag,
                wire_beta,
                prev_abs,
                &mut st.memory,
                mu_curr,
                sigma_curr,
                n,
                &mut scratch.ghat,
            )?;
            for (g, &s) in scratch.ghat.iter_mut().zip(&signs) {
                *g *= s;
            }
            let q = Quantized { codes, escapes };
            quant::dequantize_checked(&q, &scratch.ghat, delta, &mut recon)?;
            report.pred_tag = ptag.name().to_string();
        }
    }
    // Mirror update — skipped in state-free mode (see
    // `compress_layer_impl`: neither side will ever read it back).
    if !state_free_mode(cfg) {
        st.pred = cfg.predictor.mag.state_tag();
        st.eb = cfg.error_bound.state_bits();
        match wire_pred {
            None => st.absorb(&recon),
            Some((ptag, wire_beta)) => absorb_with_tag(ptag, wire_beta, st, &recon),
        }
    }
    Ok((recon, report))
}

/// Parse one post-lossless layer section *as integer bins*, stopping
/// before dequantization — the server-side fast path for
/// [`crate::compress::agg`]. Returns `Ok(None)` when the section fails
/// the frame-level validity conditions (lossless small layer, v1/v2
/// implicit-EMA section, a predictor tag other than `zero`, or sign
/// side-info present), in which case the caller falls back to the dense
/// decode. With `pred=zero` and `sign=none` the prediction is
/// identically zero, so `recon = 2Δ·code` (escapes exact) and the
/// returned `(codes, escapes, Δ)` triple fully determines the layer.
fn decode_layer_bins_impl(
    meta: &LayerMeta,
    section: &[u8],
) -> crate::Result<Option<(Vec<i32>, Vec<f32>, f64, LayerReport)>> {
    let mut r = BlobReader::new(section);
    let tag = r.get_u8()?;
    if tag != SECTION_LOSSY_V3 {
        // Lossless small layers and implicit-EMA (v1/v2) sections carry
        // no predictor tag to validate against — dense fallback.
        return Ok(None);
    }
    let coder = read_section_coder(&mut r, tag)
        .map_err(|e| anyhow::anyhow!("layer {}: {e}", meta.name))?;
    let (ptag, _beta) =
        read_pred_suffix(&mut r).map_err(|e| anyhow::anyhow!("layer {}: {e}", meta.name))?;
    if ptag != PredTag::Zero {
        return Ok(None);
    }
    let n = r.get_u32()? as usize;
    if n != meta.numel {
        anyhow::bail!("layer {}: payload numel {} != meta {}", meta.name, n, meta.numel);
    }
    let _mu_curr = r.get_f32()?;
    let _sigma_curr = r.get_f32()?;
    let delta = r.get_f64()?;
    anyhow::ensure!(
        delta.is_finite() && delta > 0.0,
        "layer {}: bad delta {delta}",
        meta.name
    );
    let sign_bytes = r.get_bytes()?;
    if !matches!(SignMeta::decode_bounded(sign_bytes, n)?, SignMeta::None) {
        // Sign side-info implies a non-zero prediction — dense fallback.
        return Ok(None);
    }
    let entropy = r.get_bytes()?;
    let (codes, _) = coder.decode_bounded(entropy, n)?;
    if codes.len() != n {
        anyhow::bail!("layer {}: {} codes for {} elements", meta.name, codes.len(), n);
    }
    let escapes = r.get_f32_vec()?;
    anyhow::ensure!(
        quant::count_escapes(&codes) == escapes.len(),
        "layer {}: {} escape markers for {} escape values",
        meta.name,
        quant::count_escapes(&codes),
        escapes.len()
    );
    let report = LayerReport {
        name: meta.name.clone(),
        raw_bytes: n * 4,
        entropy_bytes: entropy.len(),
        entropy_coder: coder.name().to_string(),
        pred_tag: PredTag::Zero.name().to_string(),
        lossy: true,
        escape_count: escapes.len(),
        side_info_bytes: sign_bytes.len() + escapes.len() * 4,
        ..Default::default()
    };
    Ok(Some((codes, escapes, delta, report)))
}

/// The stateless decode engine of the FedGEC codec: configuration (plus
/// an optional PJRT/HLO predict backend) only — per-client predictor
/// state arrives explicitly with every call, so one engine serves an
/// entire federation (the server pairs it with a
/// [`crate::compress::store::StateStore`]).
pub struct FedgecEngine {
    pub cfg: FedgecConfig,
    /// Optional PJRT/HLO predict engine; `None` ⇒ native fused path.
    pub engine: Option<Box<dyn PredictBackend>>,
    /// The active round's error-bound plan — the *same* plan the clients
    /// received, applied by the server/edge before decoding, so the
    /// mirror's eb tag matches the client's bit for bit. (Dense decode
    /// itself never needs it: every lossy section self-describes its Δ.)
    pub plan: Option<EbPlan>,
    /// Reusable decode scratch (frames decode sequentially per call, so
    /// one slot serves every layer and every client).
    scratch: LayerScratch,
}

impl FedgecEngine {
    pub fn new(cfg: FedgecConfig) -> Self {
        FedgecEngine { cfg, engine: None, plan: None, scratch: LayerScratch::default() }
    }

    pub fn with_engine(cfg: FedgecConfig, engine: Box<dyn PredictBackend>) -> Self {
        FedgecEngine { cfg, engine: Some(engine), plan: None, scratch: LayerScratch::default() }
    }
}

impl crate::compress::engine::CodecEngine for FedgecEngine {
    fn name(&self) -> &'static str {
        "fedgec"
    }

    fn stateful(&self) -> bool {
        // State-free configurations (pred=zero + sign=none, no autotune)
        // decode without ever touching the mirror, so the server skips
        // the store and the epoch handshake like any stateless family.
        !state_free_mode(&self.cfg)
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
        state: &mut CodecState,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        let idx = frame.index as usize;
        state.ensure(idx + 1);
        let cfg = effective_cfg(&self.cfg, self.plan.as_ref(), idx);
        let section = lossless::decompress(&frame.payload)?;
        let (data, mut report) = decompress_layer_impl(
            &cfg,
            meta,
            &section,
            &mut state.layers[idx],
            &mut self.scratch,
            self.engine.as_deref_mut(),
        )?;
        report.compressed_bytes = frame.wire_size();
        Ok((LayerGrad::new(meta.clone(), data), report))
    }

    fn apply_eb_plan(&mut self, plan: &EbPlan) {
        self.plan = Some(plan.clone());
    }

    /// Bins fast path: eligible only in state-free mode under an
    /// absolute error bound (every client then shares one Δ per layer,
    /// the validity condition for integer bin summation — rel-eb Δ is
    /// data-dependent per client, so it deterministically routes dense).
    /// Frame-level conditions are re-checked against the wire bytes;
    /// any miss falls back to the full dense decode.
    fn decode_frame_to_bins(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
        state: &mut CodecState,
    ) -> crate::Result<(super::agg::BinFrame, LayerReport)> {
        use crate::compress::engine::CodecEngine as _;
        let eligible =
            state_free_mode(&self.cfg) && matches!(self.cfg.error_bound, ErrorBound::Abs(_));
        if eligible {
            let section = lossless::decompress(&frame.payload)?;
            if let Some((codes, escapes, delta, mut report)) =
                decode_layer_bins_impl(meta, &section)?
            {
                report.compressed_bytes = frame.wire_size();
                report.agg_route = "binsum".into();
                let bf =
                    super::agg::BinFrame::Bins { codes, escapes, pred: Vec::new(), delta };
                return Ok((bf, report));
            }
        }
        let (layer, mut report) = self.decode_frame(frame, meta, state)?;
        report.agg_route = "exact".into();
        Ok((super::agg::BinFrame::Dense(layer), report))
    }
}

impl GradientCodec for FedgecCodec {
    fn begin(&mut self, n_layers: usize) -> crate::Result<()> {
        self.state.ensure(n_layers);
        self.ensure_ctrl(n_layers);
        self.ensure_scratch(n_layers);
        Ok(())
    }

    fn encode_layer(&mut self, idx: usize, layer: &LayerGrad) -> crate::Result<Frame> {
        self.state.ensure(idx + 1);
        self.ensure_ctrl(idx + 1);
        self.ensure_scratch(idx + 1);
        let ctrl = if use_tau_ctrl(&self.cfg) { Some(&mut self.tau_ctrl[idx]) } else { None };
        let cfg = effective_cfg(&self.cfg, self.plan.as_ref(), idx);
        // Encode timing is new instrumentation (nothing measured it
        // before), so the clock reads are gated on an attached sink.
        let t0 = crate::telemetry::active().then(std::time::Instant::now);
        let (payload, report) = compress_layer_impl(
            &cfg,
            layer,
            &mut self.state.layers[idx],
            ctrl,
            &mut self.scratch[idx],
            self.engine.as_deref_mut(),
        )?;
        if let Some(t0) = t0 {
            crate::telemetry::ENCODE_NS.add_duration(t0.elapsed());
        }
        Ok(Frame::new(idx, payload, report))
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        let idx = frame.index as usize;
        self.state.ensure(idx + 1);
        self.ensure_scratch(idx + 1);
        let cfg = effective_cfg(&self.cfg, self.plan.as_ref(), idx);
        let section = lossless::decompress(&frame.payload)?;
        let (data, mut report) = decompress_layer_impl(
            &cfg,
            meta,
            &section,
            &mut self.state.layers[idx],
            &mut self.scratch[idx],
            self.engine.as_deref_mut(),
        )?;
        report.compressed_bytes = frame.wire_size();
        Ok((LayerGrad::new(meta.clone(), data), report))
    }

    /// Layer-parallel whole-model encode: each layer's predictor state is
    /// independent, so large models fan out across worker threads.
    fn encode_model(&mut self, grads: &ModelGrad) -> crate::Result<Vec<Frame>> {
        let n = grads.layers.len();
        self.begin(n)?;
        let threads = self.encode_threads(grads);
        if threads <= 1 {
            let mut frames = Vec::with_capacity(n);
            for (idx, layer) in grads.layers.iter().enumerate() {
                frames.push(self.encode_layer(idx, layer)?);
            }
            return Ok(frames);
        }
        let use_ctrl = use_tau_ctrl(&self.cfg);
        // Per-layer effective configs: under an eb plan each layer may
        // carry its own bound, so the workers get `&cfgs[idx]` instead of
        // one shared `&self.cfg` (a Cow borrow when no plan is active).
        let cfgs: Vec<std::borrow::Cow<FedgecConfig>> =
            (0..n).map(|idx| effective_cfg(&self.cfg, self.plan.as_ref(), idx)).collect();
        let mut ctrl_iter = if use_ctrl { Some(self.tau_ctrl.iter_mut()) } else { None };
        type Item<'a> = (
            &'a FedgecConfig,
            &'a LayerGrad,
            &'a mut LayerState,
            Option<&'a mut TauController>,
            &'a mut LayerScratch,
        );
        let items: Vec<Item> = cfgs
            .iter()
            .zip(grads.layers.iter())
            .zip(self.state.layers.iter_mut())
            .zip(self.scratch.iter_mut())
            .map(|(((cfg, layer), st), scratch)| {
                let ctrl = ctrl_iter.as_mut().and_then(|it| it.next());
                (cfg.as_ref(), layer, st, ctrl, scratch)
            })
            .collect();
        let results = crate::util::threadpool::parallel_map(
            items,
            threads,
            |(cfg, layer, st, ctrl, scratch)| {
                let t0 = crate::telemetry::active().then(std::time::Instant::now);
                let res = compress_layer_impl(cfg, layer, st, ctrl, scratch, None);
                if let Some(t0) = t0 {
                    crate::telemetry::ENCODE_NS.add_duration(t0.elapsed());
                }
                res
            },
        );
        let mut frames = Vec::with_capacity(n);
        for (idx, res) in results.into_iter().enumerate() {
            let (payload, report) = res?;
            frames.push(Frame::new(idx, payload, report));
        }
        Ok(frames)
    }

    fn name(&self) -> &'static str {
        "fedgec"
    }

    fn reset(&mut self) {
        // The eb plan deliberately survives: it is round-scoped server
        // config, not mirrored state — a client resyncing mid-round must
        // keep the current round's broadcast bound.
        self.state.reset();
        self.tau_ctrl.clear();
    }

    fn apply_eb_plan(&mut self, plan: &EbPlan) {
        self.plan = Some(plan.clone());
    }

    fn state_fingerprint(&self) -> u64 {
        self.state.fingerprint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::LayerMeta;
    use crate::util::rng::Rng;

    fn make_grads(rng: &mut Rng, scale: f32) -> ModelGrad {
        // One conv layer with dominant-sign kernels + one dense + one bias.
        let t = 9;
        let n_kernels = 128;
        let mut conv = Vec::with_capacity(n_kernels * t);
        for _ in 0..n_kernels {
            let dom: f32 = if rng.chance(0.5) { 1.0 } else { -1.0 };
            for _ in 0..t {
                let flip = rng.chance(0.12);
                conv.push(dom * if flip { -1.0 } else { 1.0 } * (0.2 + rng.next_f32()) * scale);
            }
        }
        let dense: Vec<f32> = (0..2048).map(|_| rng.normal_f32(0.0, scale)).collect();
        let bias: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, scale)).collect();
        ModelGrad {
            layers: vec![
                LayerGrad::new(LayerMeta::conv("conv", n_kernels, 1, 3, 3), conv),
                LayerGrad::new(LayerMeta::dense("dense", 32, 64), dense),
                LayerGrad::new(LayerMeta::other("bias", 16), bias),
            ],
        }
    }

    fn metas(g: &ModelGrad) -> Vec<LayerMeta> {
        g.layers.iter().map(|l| l.meta.clone()).collect()
    }

    #[test]
    fn roundtrip_respects_error_bound_over_rounds() {
        let mut rng = Rng::new(1);
        let mut client = FedgecCodec::new(FedgecConfig::default());
        let mut server = FedgecCodec::new(FedgecConfig::default());
        for round in 0..5 {
            let scale = 1.0 / (1.0 + round as f32 * 0.3);
            let grads = make_grads(&mut rng, scale);
            let payload = client.compress(&grads).unwrap();
            let recon = server.decompress(&payload, &metas(&grads)).unwrap();
            // Bias layer (small) must be exact.
            assert_eq!(recon.layers[2].data, grads.layers[2].data);
            // Lossy layers within REL bound.
            for li in 0..2 {
                let (lo, hi) = stats::finite_min_max(&grads.layers[li].data);
                let delta = FedgecConfig::default().error_bound.resolve(lo, hi) as f32;
                for (r, g) in recon.layers[li].data.iter().zip(&grads.layers[li].data) {
                    assert!((r - g).abs() <= delta * 1.0001, "round {round} layer {li}");
                }
            }
            // Client/server states stay synchronized.
            assert_eq!(client.state.fingerprint(), server.state.fingerprint());
        }
    }

    #[test]
    fn compresses_structured_gradients_well() {
        let mut rng = Rng::new(2);
        let mut client = FedgecCodec::new(FedgecConfig {
            error_bound: ErrorBound::Rel(3e-2),
            ..Default::default()
        });
        // Warm up two rounds so the predictor has history.
        let mut ratio = 0.0;
        for _ in 0..4 {
            let grads = make_grads(&mut rng, 1.0);
            let payload = client.compress(&grads).unwrap();
            ratio = grads.byte_size() as f64 / payload.len() as f64;
        }
        assert!(ratio > 4.0, "expected CR > 4, got {ratio:.2}");
    }

    #[test]
    fn frame_api_matches_whole_model_adapter() {
        // Session encoding must produce byte-identical frames to the
        // blanket adapter (same per-layer state evolution).
        let mut rng = Rng::new(11);
        let g = make_grads(&mut rng, 1.0);
        let mut a = FedgecCodec::new(FedgecConfig::default());
        let mut b = FedgecCodec::new(FedgecConfig::default());
        let payload = a.compress(&g).unwrap();
        b.begin(g.layers.len()).unwrap();
        let mut frames = Vec::new();
        for (idx, layer) in g.layers.iter().enumerate() {
            frames.push(b.encode_layer(idx, layer).unwrap());
        }
        assert_eq!(payload, crate::compress::frame::frames_to_payload(&frames));
    }

    #[test]
    fn parallel_encode_matches_sequential() {
        // A model large enough to trip the parallel path must produce the
        // exact payload of per-layer sequential encoding.
        let mut rng = Rng::new(12);
        let n = 40_000; // 4 layers x 40k elements > PARALLEL_MIN_NUMEL
        let layers: Vec<LayerGrad> = (0..4)
            .map(|i| {
                let data: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.5)).collect();
                LayerGrad::new(LayerMeta::dense(&format!("fc{i}"), n, 1), data)
            })
            .collect();
        let g = ModelGrad { layers };
        let mut par = FedgecCodec::new(FedgecConfig::default());
        let mut seq = FedgecCodec::new(FedgecConfig::default());
        for _ in 0..3 {
            let frames_par = par.encode_model(&g).unwrap();
            seq.begin(g.layers.len()).unwrap();
            let frames_seq: Vec<_> = g
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| seq.encode_layer(i, l).unwrap())
                .collect();
            assert_eq!(frames_par.len(), frames_seq.len());
            for (a, b) in frames_par.iter().zip(&frames_seq) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.payload, b.payload);
            }
            assert_eq!(par.state.fingerprint(), seq.state.fingerprint());
        }
    }

    #[test]
    fn reports_flow_through_trait() {
        let mut rng = Rng::new(13);
        let g = make_grads(&mut rng, 1.0);
        let mut client = FedgecCodec::new(FedgecConfig::default());
        let mut server = FedgecCodec::new(FedgecConfig::default());
        let (payload, creport) = client.compress_with_report(&g).unwrap();
        assert_eq!(creport.layers.len(), 3);
        assert_eq!(creport.total_raw(), g.byte_size());
        assert!(creport.layers[0].lossy && !creport.layers[2].lossy);
        // Wire accounting matches the actual payload (count header + frames).
        assert_eq!(creport.total_compressed() + 4, payload.len());
        let (_, sreport) = server.decompress_with_report(&payload, &metas(&g)).unwrap();
        assert_eq!(sreport.total_raw(), creport.total_raw());
        assert_eq!(sreport.total_compressed(), creport.total_compressed());
    }

    #[test]
    fn reset_clears_state() {
        let mut rng = Rng::new(3);
        let mut c = FedgecCodec::new(FedgecConfig::default());
        let g = make_grads(&mut rng, 1.0);
        c.compress(&g).unwrap();
        assert!(c.state.layers.iter().any(|l| l.prev_recon.is_some()));
        c.reset();
        assert!(c.state.layers.iter().all(|l| l.prev_recon.is_none()));
    }

    #[test]
    fn wrong_meta_count_errors() {
        let mut rng = Rng::new(4);
        let mut c = FedgecCodec::new(FedgecConfig::default());
        let g = make_grads(&mut rng, 1.0);
        let payload = c.compress(&g).unwrap();
        let mut s = FedgecCodec::new(FedgecConfig::default());
        let bad_metas = &metas(&g)[..2];
        assert!(s.decompress(&payload, bad_metas).is_err());
    }

    #[test]
    fn full_batch_mode_roundtrips() {
        let mut rng = Rng::new(5);
        let cfg = FedgecConfig { full_batch: true, ..Default::default() };
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg);
        let base = make_grads(&mut rng, 1.0);
        for round in 0..4 {
            // Oscillating gradients: alternate global sign.
            let mut g = base.clone();
            let flip = if round % 2 == 0 { 1.0f32 } else { -1.0 };
            for l in &mut g.layers {
                for v in &mut l.data {
                    *v *= flip * (1.0 + 0.05 * rng.gauss() as f32);
                }
            }
            let payload = client.compress(&g).unwrap();
            let recon = server.decompress(&payload, &metas(&g)).unwrap();
            assert_eq!(client.state.fingerprint(), server.state.fingerprint());
            let _ = recon;
        }
    }

    #[test]
    fn autotune_stays_synchronized_and_bounded() {
        let mut rng = Rng::new(21);
        let cfg = FedgecConfig { autotune: true, ..Default::default() };
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg);
        for round in 0..6 {
            let grads = make_grads(&mut rng, 1.0 / (1.0 + round as f32 * 0.2));
            let payload = client.compress(&grads).unwrap();
            let recon = server.decompress(&payload, &metas(&grads)).unwrap();
            // Error bound still holds under auto-tuned parameters.
            for li in 0..2 {
                let (lo, hi) = stats::finite_min_max(&grads.layers[li].data);
                let delta = FedgecConfig::default().error_bound.resolve(lo, hi) as f32;
                for (r, g) in recon.layers[li].data.iter().zip(&grads.layers[li].data) {
                    assert!((r - g).abs() <= delta * 1.0001, "round {round}");
                }
            }
            assert_eq!(
                client.state.fingerprint(),
                server.state.fingerprint(),
                "autotune broke sync at round {round}"
            );
        }
        // The controller actually moved (or at least exists) per layer.
        assert!(!client.tau_ctrl.is_empty());
        for c in &client.tau_ctrl {
            assert!((c.min_tau..=c.max_tau).contains(&c.tau));
        }
    }

    #[test]
    fn rans_pipeline_roundtrips_and_pins_v2_header() {
        let mut rng = Rng::new(31);
        let cfg = FedgecConfig {
            entropy: EntropyCoder::Rans,
            backend: Backend::None,
            ..Default::default()
        };
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg);
        for round in 0..3 {
            let grads = make_grads(&mut rng, 1.0);
            let payload = client.compress(&grads).unwrap();
            // Golden blob header: lossy sections open with the v2 tag and
            // the recorded rANS coder tag.
            let frames = crate::compress::frame::payload_to_frames(&payload).unwrap();
            let section = lossless::decompress(&frames[0].payload).unwrap();
            assert_eq!(section[0], crate::compress::blob::SECTION_LOSSY_V2, "round {round}");
            assert_eq!(section[1], EntropyCoder::Rans.tag(), "round {round}");
            let recon = server.decompress(&payload, &metas(&grads)).unwrap();
            for li in 0..2 {
                let (lo, hi) = stats::finite_min_max(&grads.layers[li].data);
                let delta = FedgecConfig::default().error_bound.resolve(lo, hi) as f32;
                for (r, g) in recon.layers[li].data.iter().zip(&grads.layers[li].data) {
                    assert!((r - g).abs() <= delta * 1.0001, "round {round} layer {li}");
                }
            }
            assert_eq!(client.state.fingerprint(), server.state.fingerprint());
        }
    }

    #[test]
    fn rans_and_huffman_pipelines_reconstruct_identically() {
        // The entropy stage is lossless, so the two coders must yield
        // bit-identical reconstructions and predictor states.
        let mut rng = Rng::new(32);
        let mut huff = FedgecCodec::new(FedgecConfig::default());
        let mut rans = FedgecCodec::new(FedgecConfig {
            entropy: EntropyCoder::Rans,
            ..Default::default()
        });
        let mut huff_srv = FedgecCodec::new(FedgecConfig::default());
        let mut rans_srv = FedgecCodec::new(FedgecConfig {
            entropy: EntropyCoder::Rans,
            ..Default::default()
        });
        for _ in 0..3 {
            let grads = make_grads(&mut rng, 1.0);
            let ph = huff.compress(&grads).unwrap();
            let pr = rans.compress(&grads).unwrap();
            let rh = huff_srv.decompress(&ph, &metas(&grads)).unwrap();
            let rr = rans_srv.decompress(&pr, &metas(&grads)).unwrap();
            for (a, b) in rh.layers.iter().zip(&rr.layers) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            assert_eq!(huff.state.fingerprint(), rans.state.fingerprint());
        }
    }

    #[test]
    fn engine_decode_matches_mirrored_codec() {
        // The stateless engine + external state must reproduce the old
        // one-mirror-per-client decode bit for bit, including the state
        // evolution across rounds.
        use crate::compress::engine::CodecEngine;
        let mut rng = Rng::new(41);
        let mut client = FedgecCodec::new(FedgecConfig::default());
        let mut mirror = FedgecCodec::new(FedgecConfig::default());
        let mut engine = FedgecEngine::new(FedgecConfig::default());
        let mut state = CodecState::default();
        assert!(engine.stateful());
        for round in 0..4 {
            let grads = make_grads(&mut rng, 1.0 / (1.0 + round as f32 * 0.25));
            let payload = client.compress(&grads).unwrap();
            let via_mirror = mirror.decompress(&payload, &metas(&grads)).unwrap();
            let (via_engine, report) =
                engine.decode_payload(&payload, &metas(&grads), &mut state).unwrap();
            for (a, b) in via_mirror.layers.iter().zip(&via_engine.layers) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "round {round}");
                }
            }
            assert_eq!(report.layers.len(), grads.layers.len());
            assert_eq!(state.fingerprint(), mirror.state.fingerprint(), "round {round}");
            assert_eq!(state.fingerprint(), client.state_fingerprint(), "round {round}");
        }
    }

    fn cfg_with(mag: MagnitudeSel, sign: SignSel) -> FedgecConfig {
        FedgecConfig { predictor: PredictorSpec { mag, sign }, ..Default::default() }
    }

    fn assert_bound_and_sync(
        cfg: FedgecConfig,
        rounds: usize,
        seed: u64,
    ) -> (Vec<crate::compress::frame::CodecReport>, Vec<crate::compress::frame::CodecReport>) {
        let mut rng = Rng::new(seed);
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg);
        let mut creports = Vec::new();
        let mut sreports = Vec::new();
        for round in 0..rounds {
            let grads = make_grads(&mut rng, 1.0 / (1.0 + round as f32 * 0.3));
            let (payload, cr) = client.compress_with_report(&grads).unwrap();
            let (recon, sr) = server.decompress_with_report(&payload, &metas(&grads)).unwrap();
            for li in 0..2 {
                let (lo, hi) = stats::finite_min_max(&grads.layers[li].data);
                let delta = FedgecConfig::default().error_bound.resolve(lo, hi) as f32;
                for (r, g) in recon.layers[li].data.iter().zip(&grads.layers[li].data) {
                    assert!((r - g).abs() <= delta * 1.0001, "round {round} layer {li}");
                }
            }
            assert_eq!(
                client.state.fingerprint(),
                server.state.fingerprint(),
                "round {round}"
            );
            creports.push(cr);
            sreports.push(sr);
        }
        (creports, sreports)
    }

    #[test]
    fn fixed_predictors_roundtrip_with_self_describing_frames() {
        // pred=last / pred=zero: bound + mirror sync hold, frames open
        // with the v3 section recording the predictor tag, and encoder/
        // decoder report the same tag per layer.
        for (mag, want) in [(MagnitudeSel::Last, "last"), (MagnitudeSel::Zero, "zero")] {
            let cfg = FedgecConfig {
                backend: Backend::None,
                ..cfg_with(mag, SignSel::Auto)
            };
            let mut rng = Rng::new(51);
            let g = make_grads(&mut rng, 1.0);
            let mut probe = FedgecCodec::new(cfg.clone());
            let frames = probe.encode_model(&g).unwrap();
            let section = lossless::decompress(&frames[0].payload).unwrap();
            assert_eq!(section[0], SECTION_LOSSY_V3, "{want}");
            assert_eq!(section[1], EntropyCoder::Huffman.tag(), "{want}");
            assert_eq!(section[2], if want == "last" { 1 } else { 2 }, "{want} wire tag");
            let (creports, sreports) = assert_bound_and_sync(cfg, 4, 52);
            for (cr, sr) in creports.iter().zip(&sreports) {
                for (cl, sl) in cr.layers.iter().zip(&sr.layers) {
                    assert_eq!(cl.pred_tag, sl.pred_tag, "{want} layer {}", cl.name);
                    if cl.lossy {
                        assert_eq!(cl.pred_tag, want);
                        assert!(cl.pred_race.is_empty(), "fixed predictors don't race");
                    }
                }
            }
        }
    }

    /// Near-stationary stream builder: stable dominant-sign conv pattern
    /// (few flips) + a dense layer, mild decay and small jitter — the
    /// regime where cross-round predictors demonstrably beat `zero` on
    /// the conv layer from round 2, while round 1 (no history) and the
    /// sign-less dense layer (ĝ = S⊙â = 0 for every candidate) tie and
    /// deterministically fall to `zero`.
    fn correlated_base(rng: &mut Rng) -> ModelGrad {
        let t = 9;
        let n_kernels = 128;
        let mut conv = Vec::with_capacity(n_kernels * t);
        for _ in 0..n_kernels {
            let dom: f32 = if rng.chance(0.5) { 1.0 } else { -1.0 };
            for _ in 0..t {
                let flip = rng.chance(0.05);
                conv.push(dom * if flip { -1.0 } else { 1.0 } * (0.2 + rng.next_f32()));
            }
        }
        let dense: Vec<f32> = (0..2048).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bias: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        ModelGrad {
            layers: vec![
                LayerGrad::new(LayerMeta::conv("conv", n_kernels, 1, 3, 3), conv),
                LayerGrad::new(LayerMeta::dense("dense", 32, 64), dense),
                LayerGrad::new(LayerMeta::other("bias", 16), bias),
            ],
        }
    }

    #[test]
    fn pred_auto_races_and_roundtrips() {
        let cfg = cfg_with(MagnitudeSel::Auto, SignSel::Auto);
        let mut rng = Rng::new(53);
        let base = correlated_base(&mut rng);
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg);
        let mut seen = std::collections::BTreeSet::new();
        for round in 0..5 {
            let mut g = base.clone();
            let scale = 1.0 / (1.0 + round as f32 * 0.05);
            for l in &mut g.layers {
                for v in &mut l.data {
                    *v *= scale * (1.0 + 0.02 * rng.gauss() as f32);
                }
            }
            let (payload, cr) = client.compress_with_report(&g).unwrap();
            let (recon, sr) = server.decompress_with_report(&payload, &metas(&g)).unwrap();
            for li in 0..2 {
                let (lo, hi) = stats::finite_min_max(&g.layers[li].data);
                let delta = FedgecConfig::default().error_bound.resolve(lo, hi) as f32;
                for (r, x) in recon.layers[li].data.iter().zip(&g.layers[li].data) {
                    assert!((r - x).abs() <= delta * 1.0001, "round {round} layer {li}");
                }
            }
            assert_eq!(client.state.fingerprint(), server.state.fingerprint(), "round {round}");
            for (cl, sl) in cr.layers.iter().zip(&sr.layers) {
                assert_eq!(cl.pred_tag, sl.pred_tag, "layer {}", cl.name);
                if !cl.lossy {
                    continue;
                }
                seen.insert(cl.pred_tag.clone());
                // The race log covers all three candidates and the
                // recorded winner is its exact argmin.
                assert_eq!(cl.pred_race.len(), 3, "layer {}", cl.name);
                let min = cl.pred_race.iter().map(|&(_, c)| c).min().unwrap();
                let winner = cl
                    .pred_race
                    .iter()
                    .find(|(name, _)| *name == cl.pred_tag)
                    .unwrap_or_else(|| panic!("winner {} not in race log", cl.pred_tag));
                assert_eq!(winner.1, min, "layer {}: winner must be the argmin", cl.name);
                if round == 0 {
                    assert_eq!(cl.pred_tag, "zero", "cold round ties fall to zero");
                }
            }
        }
        assert!(seen.len() >= 2, "expected mixed winners, saw {seen:?}");
    }

    #[test]
    fn sign_none_sends_no_side_info() {
        let cfg = cfg_with(MagnitudeSel::Ema, SignSel::None);
        let mut rng = Rng::new(54);
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg);
        for _ in 0..3 {
            let grads = make_grads(&mut rng, 1.0);
            let (payload, cr) = client.compress_with_report(&grads).unwrap();
            server.decompress(&payload, &metas(&grads)).unwrap();
            assert_eq!(client.state.fingerprint(), server.state.fingerprint());
            for l in cr.layers.iter().filter(|l| l.lossy) {
                assert_eq!(l.sign_stats.elements_predicted, 0);
                // SignMeta::None is a single tag byte.
                assert_eq!(l.side_info_bytes, 1 + l.escape_count * 4, "layer {}", l.name);
            }
        }
    }

    #[test]
    fn pred_auto_with_rans_and_autotune_stays_synchronized() {
        // The race composes with the other client-only decisions (per-
        // layer coder choice, β schedule) without breaking the mirror.
        let cfg = FedgecConfig {
            entropy: EntropyCoder::Rans,
            autotune: true,
            ..cfg_with(MagnitudeSel::Auto, SignSel::Auto)
        };
        assert_bound_and_sync(cfg, 4, 55);
    }

    #[test]
    fn state_free_mode_keeps_mirrors_cold() {
        // pred=zero + sign=none + abs-eb: decode never touches state, so
        // client and server fingerprints stay at the cold default across
        // rounds and the engine declares itself stateless.
        use crate::compress::engine::CodecEngine;
        let cfg = FedgecConfig {
            error_bound: ErrorBound::Abs(5e-3),
            ..cfg_with(MagnitudeSel::Zero, SignSel::None)
        };
        assert!(state_free_mode(&cfg));
        assert!(!FedgecEngine::new(cfg.clone()).stateful());
        let cold = CodecState::default().fingerprint();
        let mut rng = Rng::new(61);
        let mut client = FedgecCodec::new(cfg.clone());
        let mut server = FedgecCodec::new(cfg.clone());
        for round in 0..4 {
            let grads = make_grads(&mut rng, 1.0);
            let payload = client.compress(&grads).unwrap();
            let recon = server.decompress(&payload, &metas(&grads)).unwrap();
            for li in 0..2 {
                for (r, g) in recon.layers[li].data.iter().zip(&grads.layers[li].data) {
                    assert!((r - g).abs() <= 5e-3 * 1.0001, "round {round} layer {li}");
                }
            }
            assert_eq!(client.state.fingerprint(), cold, "round {round}: client warmed");
            assert_eq!(server.state.fingerprint(), cold, "round {round}: server warmed");
        }
        // Autotune re-enables state (history-derived β schedule).
        let tuned = FedgecConfig { autotune: true, ..client.cfg.clone() };
        assert!(!state_free_mode(&tuned));
        assert!(FedgecEngine::new(tuned).stateful());
    }

    #[test]
    fn bins_decode_matches_dense_decode() {
        use crate::compress::agg::BinFrame;
        use crate::compress::engine::CodecEngine;
        let cfg = FedgecConfig {
            error_bound: ErrorBound::Abs(4e-3),
            ..cfg_with(MagnitudeSel::Zero, SignSel::None)
        };
        let mut rng = Rng::new(62);
        let mut client = FedgecCodec::new(cfg.clone());
        let mut engine = FedgecEngine::new(cfg.clone());
        let mut g = make_grads(&mut rng, 1.0);
        // Force some escapes into the dense layer.
        g.layers[1].data[7] = f32::NAN;
        g.layers[1].data[100] = 1e30;
        let frames = client.encode_model(&g).unwrap();
        let ms = metas(&g);
        for (frame, meta) in frames.iter().zip(&ms) {
            let mut st = CodecState::default();
            let (bf, rep) = engine.decode_frame_to_bins(frame, meta, &mut st).unwrap();
            let mut st2 = CodecState::default();
            let (dense, _) = engine.decode_frame(frame, meta, &mut st2).unwrap();
            match bf {
                BinFrame::Bins { codes, escapes, pred, delta } => {
                    assert_eq!(rep.agg_route, "binsum");
                    assert!(pred.is_empty());
                    // Reconstructing the bins through the quantizer must
                    // reproduce the dense decode bit for bit.
                    let q = Quantized { codes, escapes };
                    let zeros = vec![0.0f32; meta.numel];
                    let mut recon = Vec::new();
                    quant::dequantize_checked(&q, &zeros, delta, &mut recon).unwrap();
                    for (a, b) in recon.iter().zip(&dense.data) {
                        assert_eq!(a.to_bits(), b.to_bits(), "layer {}", meta.name);
                    }
                }
                BinFrame::Dense(layer) => {
                    // Small layers are stored lossless and route dense.
                    assert_eq!(rep.agg_route, "exact");
                    assert!(meta.numel <= cfg.t_lossy, "layer {}", meta.name);
                    assert_eq!(layer.data, dense.data);
                }
            }
        }
        // Escape accounting flowed through the bins report.
        let mut st = CodecState::default();
        let (_, rep) = engine.decode_frame_to_bins(&frames[1], &ms[1], &mut st).unwrap();
        assert!(rep.escape_count >= 2);
    }

    #[test]
    fn rel_eb_and_stateful_predictors_route_dense() {
        use crate::compress::agg::BinFrame;
        use crate::compress::engine::CodecEngine;
        // rel-eb: Δ is data-dependent per client, so even zero/none
        // frames must route dense for determinism.
        let rel = cfg_with(MagnitudeSel::Zero, SignSel::None);
        assert!(state_free_mode(&rel));
        let mut rng = Rng::new(63);
        let g = make_grads(&mut rng, 1.0);
        let frames = FedgecCodec::new(rel.clone()).encode_model(&g).unwrap();
        let mut engine = FedgecEngine::new(rel);
        let mut st = CodecState::default();
        let (bf, rep) = engine.decode_frame_to_bins(&frames[0], &metas(&g)[0], &mut st).unwrap();
        assert!(matches!(bf, BinFrame::Dense(_)));
        assert_eq!(rep.agg_route, "exact");
        // Stateful predictor (default EMA), abs-eb: engine is stateful,
        // frames are implicit-EMA — dense route, mirror must advance.
        let ema = FedgecConfig { error_bound: ErrorBound::Abs(5e-3), ..Default::default() };
        assert!(!state_free_mode(&ema));
        let frames = FedgecCodec::new(ema.clone()).encode_model(&g).unwrap();
        let mut engine = FedgecEngine::new(ema);
        assert!(engine.stateful());
        let mut st = CodecState::default();
        let (bf, rep) = engine.decode_frame_to_bins(&frames[0], &metas(&g)[0], &mut st).unwrap();
        assert!(matches!(bf, BinFrame::Dense(_)));
        assert_eq!(rep.agg_route, "exact");
        assert_ne!(st.fingerprint(), CodecState::default().fingerprint());
    }

    #[test]
    fn corrupted_payload_errors_not_panics() {
        let mut rng = Rng::new(6);
        let mut c = FedgecCodec::new(FedgecConfig::default());
        let g = make_grads(&mut rng, 1.0);
        let mut payload = c.compress(&g).unwrap();
        let mid = payload.len() / 2;
        payload[mid] ^= 0xFF;
        let mut s = FedgecCodec::new(FedgecConfig::default());
        let _ = s.decompress(&payload, &metas(&g)); // any Err is fine; must not panic
        let _ = s.decompress(&payload[..10], &metas(&g));
    }
}
