//! Stateless codec engines: the decode half of a [`GradientCodec`] with
//! the cross-round predictor state **externalized**.
//!
//! A [`CodecEngine`] owns configuration and scratch only — never
//! per-client mirror state. Every decode call takes an explicit
//! [`CodecState`] handle, so one engine instance serves any number of
//! clients: the parameter server holds *one* engine plus a bounded
//! [`crate::compress::store::StateStore`] instead of one mirrored codec
//! object per client.
//!
//! Client-side compressors keep the convenient stateful
//! [`GradientCodec`] shape (one client owns exactly one state); the
//! engine split matters where states fan out — the server.

use super::agg::BinFrame;
use super::frame::{self, CodecReport, Frame, LayerReport};
use super::state::CodecState;
use super::GradientCodec;
use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};

/// A stateless decode engine. `&mut self` covers scratch buffers and
/// attached predict backends (e.g. the PJRT/HLO engine), not client
/// state — that arrives through the `state` parameter of every call.
pub trait CodecEngine: Send {
    /// Codec family name (matches the paired `GradientCodec::name`).
    fn name(&self) -> &'static str;

    /// Whether decoding reads/writes cross-round state at all. Stateless
    /// families (sz3, qsgd, topk, raw) return `false`; the server then
    /// skips the store and the epoch handshake entirely.
    fn stateful(&self) -> bool {
        false
    }

    /// Adopt a round's error-bound plan (`ebc=` controllers, DESIGN.md
    /// §15). Dense decode never needs the bound — lossy sections
    /// self-describe their Δ — but stateful engines must tag the mirror
    /// with the round's eb exactly as the encoding client does, so the
    /// server applies the broadcast plan here before decoding. Stateless
    /// engines ignore it.
    fn apply_eb_plan(&mut self, plan: &super::control::EbPlan) {
        let _ = plan;
    }

    /// Decode one frame against the given client's state (the frame's
    /// `index` selects the per-layer slot).
    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
        state: &mut CodecState,
    ) -> crate::Result<(LayerGrad, LayerReport)>;

    /// Decode a whole monolithic payload against one client's state.
    fn decode_payload(
        &mut self,
        payload: &[u8],
        metas: &[LayerMeta],
        state: &mut CodecState,
    ) -> crate::Result<(ModelGrad, CodecReport)> {
        let frames = frame::payload_to_frames(payload)?;
        anyhow::ensure!(
            frames.len() == metas.len(),
            "payload has {} layers, expected {}",
            frames.len(),
            metas.len()
        );
        let mut report = CodecReport::new(self.name());
        let mut decoded = Vec::with_capacity(frames.len());
        for (i, (f, meta)) in frames.iter().zip(metas).enumerate() {
            anyhow::ensure!(f.index as usize == i, "frame {} out of order ({})", i, f.index);
            let (layer, rep) = self.decode_frame(f, meta, state)?;
            report.push(rep);
            decoded.push(layer);
        }
        Ok((ModelGrad { layers: decoded }, report))
    }

    /// Decode one frame *for aggregation*: engines whose quantized codes
    /// can be summed in the integer domain (see [`crate::compress::agg`])
    /// return [`BinFrame::Bins`] and stop before dequantization; everyone
    /// else — and any frame that fails the bins-route validity conditions
    /// — falls back to the full decode and returns [`BinFrame::Dense`].
    /// The chosen route is recorded in `LayerReport::agg_route`.
    fn decode_frame_to_bins(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
        state: &mut CodecState,
    ) -> crate::Result<(BinFrame, LayerReport)> {
        let (layer, mut rep) = self.decode_frame(frame, meta, state)?;
        rep.agg_route = "exact".into();
        Ok((BinFrame::Dense(layer), rep))
    }

    /// Whole-payload counterpart of `decode_frame_to_bins`, with the same
    /// frame-count and ordering checks as `decode_payload`.
    fn decode_payload_to_bins(
        &mut self,
        payload: &[u8],
        metas: &[LayerMeta],
        state: &mut CodecState,
    ) -> crate::Result<(Vec<BinFrame>, CodecReport)> {
        let frames = frame::payload_to_frames(payload)?;
        anyhow::ensure!(
            frames.len() == metas.len(),
            "payload has {} layers, expected {}",
            frames.len(),
            metas.len()
        );
        let mut report = CodecReport::new(self.name());
        let mut decoded = Vec::with_capacity(frames.len());
        for (i, (f, meta)) in frames.iter().zip(metas).enumerate() {
            anyhow::ensure!(f.index as usize == i, "frame {} out of order ({})", i, f.index);
            let (bf, rep) = self.decode_frame_to_bins(f, meta, state)?;
            report.push(rep);
            decoded.push(bf);
        }
        Ok((decoded, report))
    }
}

/// Blanket engine over any codec whose decode path carries no
/// cross-round state (sz3, qsgd, topk, raw, topk+eblc, and the server
/// side of `ef(...)`): the explicit state handle is ignored.
pub struct StatelessEngine {
    inner: Box<dyn GradientCodec>,
}

impl StatelessEngine {
    pub fn new(inner: Box<dyn GradientCodec>) -> Self {
        StatelessEngine { inner }
    }
}

impl CodecEngine for StatelessEngine {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn apply_eb_plan(&mut self, plan: &super::control::EbPlan) {
        self.inner.apply_eb_plan(plan);
    }

    fn decode_frame(
        &mut self,
        frame: &Frame,
        meta: &LayerMeta,
        _state: &mut CodecState,
    ) -> crate::Result<(LayerGrad, LayerReport)> {
        self.inner.decode_frame(frame, meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::RawCodec;
    use crate::compress::GradientCodec;
    use crate::tensor::LayerMeta;

    #[test]
    fn stateless_engine_matches_codec_decode() {
        let g = ModelGrad {
            layers: vec![
                LayerGrad::new(LayerMeta::other("a", 3), vec![1.0, -2.0, 3.0]),
                LayerGrad::new(LayerMeta::other("b", 2), vec![0.5, 0.25]),
            ],
        };
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        let payload = RawCodec.compress(&g).unwrap();
        let mut engine = StatelessEngine::new(Box::new(RawCodec));
        let mut state = CodecState::default();
        let (back, report) = engine.decode_payload(&payload, &metas, &mut state).unwrap();
        assert_eq!(back.layers[0].data, g.layers[0].data);
        assert_eq!(back.layers[1].data, g.layers[1].data);
        assert_eq!(report.layers.len(), 2);
        assert!(!engine.stateful());
        // The untouched state stays cold.
        assert!(state.layers.is_empty());
    }

    #[test]
    fn default_bins_path_is_dense_exact() {
        let g = ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("a", 3), vec![1.0, -2.0, 3.0])],
        };
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        let payload = RawCodec.compress(&g).unwrap();
        let mut engine = StatelessEngine::new(Box::new(RawCodec));
        let mut state = CodecState::default();
        let (bins, report) = engine.decode_payload_to_bins(&payload, &metas, &mut state).unwrap();
        assert_eq!(bins.len(), 1);
        match &bins[0] {
            BinFrame::Dense(layer) => assert_eq!(layer.data, g.layers[0].data),
            other => panic!("expected dense fallback, got {:?} elements", other.numel()),
        }
        assert_eq!(report.layers[0].agg_route, "exact");
    }

    #[test]
    fn engine_payload_layer_count_checked() {
        let g = ModelGrad {
            layers: vec![LayerGrad::new(LayerMeta::other("a", 2), vec![1.0, 2.0])],
        };
        let metas: Vec<LayerMeta> = g.layers.iter().map(|l| l.meta.clone()).collect();
        let payload = RawCodec.compress(&g).unwrap();
        let mut engine = StatelessEngine::new(Box::new(RawCodec));
        let mut state = CodecState::default();
        assert!(engine.decode_payload(&payload, &metas[..0], &mut state).is_err());
    }
}
