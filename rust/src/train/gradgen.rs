//! Calibrated synthetic gradient-sequence generator for full-scale models.
//!
//! Training ResNet-18/34 / Inception V1/V3 on A100s (the paper's Polaris
//! testbed) is not possible here, so Table 4 / Table 5 / Fig. 10 / Fig. 11
//! run the codecs on synthetic per-layer gradient *sequences* whose
//! statistics reproduce everything the predictors exploit:
//!
//! * **temporal magnitude structure** — a persistent per-element magnitude
//!   pattern under a decaying, jittered global scale (paper Fig. 4: the
//!   low-frequency trend the normalized EMA tracks);
//! * **kernel-level dominant-sign structure** — per-kernel persistent
//!   dominant signs with per-element agreement probability `q`, calibrated
//!   so ~60% of 3×3 kernels clear τ = 0.5 (paper Fig. 7 / Table 5);
//! * **cross-round oscillation** — optional full-batch mode where the
//!   global gradient direction anti-correlates between rounds (Fig. 5);
//! * **dataset-complexity knob** — noisier magnitudes for harder datasets
//!   (the paper's Caltech101-vs-Fashion-MNIST compressibility gap).
//!
//! The `gradgen_stats` integration test validates the generator against
//! *real* gradients from the micro models (same statistics, DESIGN.md §5).

use crate::tensor::{LayerGrad, LayerKind, LayerMeta, ModelGrad};
use crate::train::data::DatasetSpec;
use crate::util::rng::Rng;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct GradGenConfig {
    /// Per-element dominant-sign agreement probability (calibrates kernel
    /// sign consistency; 0.78 ⇒ ~60% of 3×3 kernels ≥ τ=0.5).
    pub sign_agreement: f64,
    /// Fraction of kernels whose dominant sign flips between rounds.
    pub kernel_flip_rate: f64,
    /// Multiplicative round-to-round scale jitter (log-normal σ).
    pub scale_jitter: f64,
    /// Scale decay rate per round (1/(1+decay·t)).
    pub decay: f64,
    /// Element-wise relative magnitude noise.
    pub mag_noise: f64,
    /// Per-round drift of the persistent magnitude pattern in [0,1] —
    /// the "dataset complexity" knob: more drift ⇒ the cross-round
    /// structure the EMA exploits decays faster (harder datasets).
    pub pattern_drift: f64,
    /// Full-batch oscillation mode (global sign alternation).
    pub full_batch: bool,
}

impl Default for GradGenConfig {
    fn default() -> Self {
        GradGenConfig {
            sign_agreement: 0.78,
            kernel_flip_rate: 0.05,
            scale_jitter: 0.15,
            decay: 0.08,
            mag_noise: 0.45,
            pattern_drift: 0.10,
            full_batch: false,
        }
    }
}

impl GradGenConfig {
    /// Complexity knob per dataset (harder ⇒ noisier, less predictable —
    /// the paper's observed compressibility ordering).
    pub fn for_dataset(spec: DatasetSpec) -> Self {
        let mut cfg = GradGenConfig::default();
        match spec {
            DatasetSpec::Fmnist => {
                cfg.sign_agreement = 0.82;
                cfg.pattern_drift = 0.05;
            }
            DatasetSpec::Cifar10 => {
                cfg.sign_agreement = 0.78;
                cfg.pattern_drift = 0.12;
            }
            DatasetSpec::Caltech101 => {
                cfg.sign_agreement = 0.70;
                cfg.pattern_drift = 0.30;
                cfg.kernel_flip_rate = 0.12;
            }
        }
        cfg
    }
}

struct LayerGen {
    meta: LayerMeta,
    /// Persistent per-element magnitude pattern (positive).
    pattern: Vec<f32>,
    /// Persistent per-kernel dominant signs (conv) or per-element signs.
    signs: Vec<f32>,
    /// Persistent per-kernel sign coherence (conv): probability that an
    /// element carries the dominant sign. Real models are a *mixture* —
    /// most kernels are highly coherent, a minority are incoherent —
    /// which is what yields the paper's joint (60% predictable, ~10%
    /// mismatch) statistics; an iid agreement cannot produce both.
    coherence: Vec<f64>,
    /// Layer-specific base scale.
    scale0: f32,
}

/// Round-by-round gradient generator for one model.
pub struct GradGen {
    cfg: GradGenConfig,
    layers: Vec<LayerGen>,
    rng: Rng,
    round: usize,
}

impl GradGen {
    pub fn new(metas: Vec<LayerMeta>, cfg: GradGenConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x6AD6E4);
        let layers = metas
            .into_iter()
            .map(|meta| {
                let n = meta.numel;
                // Layer scale varies by depth-ish position (hash of name).
                let scale0 = 10f64.powf(rng.uniform(-2.6, -1.6)) as f32;
                let pattern: Vec<f32> =
                    (0..n).map(|_| (0.3 + rng.laplace().abs() * 0.7) as f32).collect();
                let (signs, coherence) = match meta.kind {
                    LayerKind::Conv { .. } => {
                        let t = meta.kind.kernel_size().unwrap();
                        let k = n / t;
                        let mut s = Vec::with_capacity(k);
                        let mut c = Vec::with_capacity(k);
                        // Coherent-majority mixture (see field docs). The
                        // coherent fraction is tied to cfg.sign_agreement
                        // so the dataset-complexity knob still works.
                        let coherent_frac = (cfg.sign_agreement - 0.15).clamp(0.3, 0.9);
                        for _ in 0..k {
                            s.push(if rng.chance(0.5) { 1.0f32 } else { -1.0 });
                            c.push(if rng.chance(coherent_frac) {
                                rng.uniform(0.84, 0.98)
                            } else {
                                rng.uniform(0.50, 0.72)
                            });
                        }
                        (s, c)
                    }
                    _ => (
                        (0..n).map(|_| if rng.chance(0.5) { 1.0f32 } else { -1.0 }).collect(),
                        Vec::new(),
                    ),
                };
                LayerGen { meta, pattern, signs, coherence, scale0 }
            })
            .collect();
        GradGen { cfg, layers, rng, round: 0 }
    }

    /// Current round index (0-based; increments on each `next_round`).
    pub fn round(&self) -> usize {
        self.round
    }

    /// Advance `params` by one aggregated-SGD step over the next round's
    /// gradients (`θ ← θ − lr·g`): the synthetic global-model trajectory
    /// whose per-round delta the downlink broadcast codec compresses.
    pub fn sgd_step(&mut self, params: &mut [Vec<f32>], lr: f32) {
        let g = self.next_round();
        for (p, l) in params.iter_mut().zip(&g.layers) {
            for (w, d) in p.iter_mut().zip(&l.data) {
                *w -= lr * d;
            }
        }
    }
}

/// Shared measurement harness for the downlink bench panels: run a
/// fedgec global-delta broadcast stream at REL `eb` over `rounds` rounds
/// of a synthetic SGD trajectory (zero-initialized params, lr 0.05)
/// with a persistent `fan_out`-client subscription. Returns `(raw model
/// bytes, total delta frame bytes, total encode time)` — one definition
/// of the measurement convention instead of one per bench.
pub fn measure_downlink_delta(
    metas: &[crate::tensor::LayerMeta],
    cfg: GradGenConfig,
    seed: u64,
    eb: f64,
    fan_out: usize,
    rounds: usize,
) -> crate::Result<(usize, usize, std::time::Duration)> {
    use crate::compress::downlink::{measure_delta_stream, DownlinkCodec};
    use crate::compress::spec::{CodecSpec, SpecDefaults};
    let spec = CodecSpec::parse_with("fedgec", &SpecDefaults::with_rel_eb(eb))?;
    let mut down = DownlinkCodec::new(&spec, metas.to_vec());
    let mut gen = GradGen::new(metas.to_vec(), cfg, seed);
    let mut params: Vec<Vec<f32>> = metas.iter().map(|m| vec![0.0f32; m.numel]).collect();
    let ids: Vec<u32> = (0..fan_out as u32).collect();
    let (delta_bytes, encode_time) =
        measure_delta_stream(&mut down, &mut params, &ids, rounds, |p| gen.sgd_step(p, 0.05))?;
    let raw_bytes = metas.iter().map(|m| m.numel * 4).sum();
    Ok((raw_bytes, delta_bytes, encode_time))
}

impl GradGen {
    /// Generate the next round's gradient tensors.
    pub fn next_round(&mut self) -> ModelGrad {
        let t = self.round;
        self.round += 1;
        let mut cfg = self.cfg.clone();
        if cfg.full_batch {
            // Full-batch gradients carry no sampling noise: signs are far
            // more stable and magnitudes far less jittery (Fig. 5 regime).
            cfg.sign_agreement = cfg.sign_agreement.max(0.96);
            cfg.mag_noise *= 0.4;
            cfg.kernel_flip_rate = 0.0;
        }
        let global_flip = if cfg.full_batch && t % 2 == 1 { -1.0f32 } else { 1.0 };
        let mut out = ModelGrad::default();
        for lg in &mut self.layers {
            // Pattern drift: the persistent structure slowly re-randomizes.
            if cfg.pattern_drift > 0.0 && t > 0 {
                let d = cfg.pattern_drift as f32;
                for p in &mut lg.pattern {
                    let fresh = (0.3 + self.rng.laplace().abs() * 0.7) as f32;
                    *p = (1.0 - d) * *p + d * fresh;
                }
            }
            let jitter = (cfg.scale_jitter * self.rng.gauss()).exp();
            let scale = lg.scale0 * (jitter / (1.0 + cfg.decay * t as f64)) as f32;
            let n = lg.meta.numel;
            let mut data = Vec::with_capacity(n);
            match lg.meta.kind {
                LayerKind::Conv { .. } => {
                    let ks = lg.meta.kind.kernel_size().unwrap();
                    // Larger kernels are less sign-coherent in real models
                    // (paper Table 5: predictable fraction collapses at
                    // 7x7): shrink each kernel's coherence toward 0.5.
                    let size_shrink = (0.03 * (ks as f64 - 9.0).max(0.0) / 8.0).min(0.25);
                    // Kernel dominant signs persist, occasionally flipping.
                    for s in lg.signs.iter_mut() {
                        if self.rng.chance(cfg.kernel_flip_rate) {
                            *s = -*s;
                        }
                    }
                    for (k, &dom) in lg.signs.iter().enumerate() {
                        let q = if cfg.full_batch {
                            lg.coherence[k].max(0.96)
                        } else {
                            (lg.coherence[k] - size_shrink).max(0.5)
                        };
                        for e in 0..ks {
                            let i = k * ks + e;
                            let mag = (lg.pattern[i]
                                * (1.0 + cfg.mag_noise as f32 * self.rng.gauss() as f32))
                                .abs()
                                * scale;
                            let sign = if self.rng.chance(q) { dom } else { -dom };
                            data.push(global_flip * sign * mag);
                        }
                    }
                }
                _ => {
                    for i in 0..n {
                        let mag = (lg.pattern[i]
                            * (1.0 + cfg.mag_noise as f32 * self.rng.gauss() as f32))
                            .abs()
                            * scale;
                        // Non-conv signs: noisy under mini-batch, stable
                        // under full-batch GD.
                        let keep = if cfg.full_batch { 0.96 } else { 0.55 };
                        let sign = if self.rng.chance(keep) { lg.signs[i] } else { -lg.signs[i] };
                        data.push(global_flip * sign * mag);
                    }
                }
            }
            out.layers.push(LayerGrad::new(lg.meta.clone(), data));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::model_zoo::ModelArch;
    use crate::tensor::sign_consistency;
    use crate::util::stats;

    fn small_conv_metas() -> Vec<LayerMeta> {
        vec![LayerMeta::conv("c", 64, 16, 3, 3), LayerMeta::dense("d", 64, 256)]
    }

    #[test]
    fn kernel_consistency_calibrated_to_fig7() {
        let mut gen = GradGen::new(small_conv_metas(), GradGenConfig::default(), 1);
        let g = gen.next_round();
        let layer = &g.layers[0];
        let tau = 0.5;
        let total = layer.meta.kind.kernel_count().unwrap();
        let predicted = layer
            .kernels()
            .unwrap()
            .filter(|k| sign_consistency(k) >= tau)
            .count();
        let ratio = predicted as f64 / total as f64;
        // Paper Table 5: 60.6% for 3x3 at tau=0.5. Accept a band.
        assert!((0.40..0.80).contains(&ratio), "predict ratio {ratio}");
    }

    #[test]
    fn magnitudes_decay_over_rounds() {
        let mut gen = GradGen::new(small_conv_metas(), GradGenConfig::default(), 2);
        let early: f32 = stats::mean(
            &gen.next_round().layers[0].data.iter().map(|x| x.abs()).collect::<Vec<_>>(),
        );
        let mut late = 0.0;
        for _ in 0..30 {
            let g = gen.next_round();
            late = stats::mean(&g.layers[0].data.iter().map(|x| x.abs()).collect::<Vec<_>>());
        }
        assert!(late < early, "early {early} late {late}");
    }

    #[test]
    fn temporal_magnitude_correlation_present() {
        // Consecutive rounds' |g| must correlate (what the EMA exploits).
        let mut gen = GradGen::new(small_conv_metas(), GradGenConfig::default(), 3);
        let a: Vec<f32> = gen.next_round().layers[0].data.iter().map(|x| x.abs()).collect();
        let b: Vec<f32> = gen.next_round().layers[0].data.iter().map(|x| x.abs()).collect();
        let corr = stats::pearson(&a, &b);
        assert!(corr > 0.3, "temporal |g| correlation {corr}");
    }

    #[test]
    fn full_batch_mode_anticorrelates() {
        let cfg = GradGenConfig { full_batch: true, ..Default::default() };
        let mut gen = GradGen::new(small_conv_metas(), cfg, 4);
        let a = gen.next_round().flat();
        let b = gen.next_round().flat();
        let c = stats::gradient_correlation(&a, &b);
        assert!(c < -0.2, "oscillation corr {c}");
    }

    #[test]
    fn full_model_shapes() {
        let metas = ModelArch::ResNet18.layers(10);
        let mut gen = GradGen::new(metas.clone(), GradGenConfig::default(), 5);
        let g = gen.next_round();
        assert_eq!(g.layers.len(), metas.len());
        assert_eq!(g.numel(), metas.iter().map(|m| m.numel).sum::<usize>());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = GradGen::new(small_conv_metas(), GradGenConfig::default(), 7);
        let mut b = GradGen::new(small_conv_metas(), GradGenConfig::default(), 7);
        assert_eq!(a.next_round().flat(), b.next_round().flat());
    }
}
