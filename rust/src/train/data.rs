//! Synthetic dataset generators standing in for the paper's datasets
//! (Table 3). Images are class-conditional Gaussian blobs — genuinely
//! learnable (accuracy climbs well above chance within a few epochs) while
//! requiring no downloads. Shapes mirror the paper's datasets; Caltech101
//! keeps its 101 classes but is rendered at 32×32 (documented substitution,
//! DESIGN.md §5). Grayscale sets are replicated to 3 channels so one model
//! stem serves all datasets.

use crate::util::rng::Rng;

/// Dataset archetypes from the paper's Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetSpec {
    /// Fashion-MNIST-like: grayscale, 10 classes, low complexity.
    Fmnist,
    /// CIFAR-10-like: RGB, 10 classes, medium complexity.
    Cifar10,
    /// Caltech101-like: 101 classes, high complexity (more noise, more
    /// classes ⇒ less regular gradients, as in the paper's analysis).
    Caltech101,
}

impl DatasetSpec {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetSpec::Fmnist => "fmnist",
            DatasetSpec::Cifar10 => "cifar10",
            DatasetSpec::Caltech101 => "caltech101",
        }
    }
    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "fmnist" | "fashion-mnist" => DatasetSpec::Fmnist,
            "cifar10" | "cifar-10" => DatasetSpec::Cifar10,
            "caltech101" => DatasetSpec::Caltech101,
            _ => return None,
        })
    }
    pub fn classes(&self) -> usize {
        match self {
            DatasetSpec::Caltech101 => 101,
            _ => 10,
        }
    }
    /// Per-pixel noise on top of the class prototype — the "complexity"
    /// knob (harder datasets ⇒ noisier gradients ⇒ lower compressibility,
    /// the paper's observed trend).
    pub fn noise(&self) -> f32 {
        match self {
            DatasetSpec::Fmnist => 0.2,
            DatasetSpec::Cifar10 => 0.35,
            DatasetSpec::Caltech101 => 0.55,
        }
    }
    /// Grayscale datasets replicate one channel.
    pub fn grayscale(&self) -> bool {
        matches!(self, DatasetSpec::Fmnist)
    }
    /// Artifact-key suffix for the HLO trainer.
    pub fn class_suffix(&self) -> &'static str {
        match self {
            DatasetSpec::Caltech101 => "c101",
            _ => "c10",
        }
    }
}

/// Image geometry shared by all synthetic sets (see module docs).
pub const IMG: [usize; 3] = [32, 32, 3];

/// A batch-shaped dataset slice owned by one client (or the eval set).
#[derive(Debug, Clone)]
pub struct DataSlice {
    /// Flat `[n, 32, 32, 3]` images.
    pub xs: Vec<f32>,
    /// `[n]` labels.
    pub ys: Vec<i32>,
    pub n: usize,
}

/// Deterministic class-prototype bank for a dataset.
pub struct SynthDataset {
    pub spec: DatasetSpec,
    protos: Vec<f32>, // [classes, 32*32*3]
}

impl SynthDataset {
    /// Prototypes derive from (dataset, seed) only, so every client and
    /// the server agree on the task.
    pub fn new(spec: DatasetSpec, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xDA7A5E7);
        let img_len = IMG.iter().product::<usize>();
        let classes = spec.classes();
        let mut protos = Vec::with_capacity(classes * img_len);
        for _ in 0..classes {
            if spec.grayscale() {
                let plane: Vec<f32> =
                    (0..IMG[0] * IMG[1]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                for px in &plane {
                    for _ in 0..IMG[2] {
                        protos.push(*px);
                    }
                }
            } else {
                for _ in 0..img_len {
                    protos.push(rng.normal_f32(0.0, 1.0));
                }
            }
        }
        SynthDataset { spec, protos }
    }

    /// Sample `n` labelled images. `class_skew` biases the label
    /// distribution toward a client-specific subset (non-IID federation);
    /// 0.0 = IID.
    pub fn sample(&self, rng: &mut Rng, n: usize, class_skew: f64) -> DataSlice {
        let classes = self.spec.classes();
        let img_len = IMG.iter().product::<usize>();
        let noise = self.spec.noise();
        // Non-IID: client prefers a random half of the classes.
        let preferred: Vec<usize> = {
            let mut ids: Vec<usize> = (0..classes).collect();
            rng.shuffle(&mut ids);
            ids.truncate((classes / 2).max(1));
            ids
        };
        let mut xs = Vec::with_capacity(n * img_len);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let y = if rng.chance(class_skew) {
                preferred[rng.next_below(preferred.len())]
            } else {
                rng.next_below(classes)
            };
            ys.push(y as i32);
            let base = &self.protos[y * img_len..(y + 1) * img_len];
            for &b in base {
                xs.push(b + rng.normal_f32(0.0, noise));
            }
        }
        DataSlice { xs, ys, n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let ds = SynthDataset::new(DatasetSpec::Cifar10, 1);
        let mut rng = Rng::new(2);
        let s = ds.sample(&mut rng, 64, 0.0);
        assert_eq!(s.xs.len(), 64 * 32 * 32 * 3);
        assert_eq!(s.ys.len(), 64);
        assert!(s.ys.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn caltech_has_101_classes() {
        let ds = SynthDataset::new(DatasetSpec::Caltech101, 1);
        let mut rng = Rng::new(3);
        let s = ds.sample(&mut rng, 2000, 0.0);
        let max = *s.ys.iter().max().unwrap();
        assert!(max >= 50, "expected wide label range, got max {max}");
        assert!(s.ys.iter().all(|&y| (0..101).contains(&y)));
    }

    #[test]
    fn grayscale_channels_identical() {
        let ds = SynthDataset::new(DatasetSpec::Fmnist, 1);
        // Prototype channels replicated (noise differs per channel though,
        // so check the prototype bank directly).
        let img_len: usize = IMG.iter().product();
        let p = &ds.protos[..img_len];
        for px in p.chunks(3) {
            assert_eq!(px[0], px[1]);
            assert_eq!(px[1], px[2]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SynthDataset::new(DatasetSpec::Cifar10, 7);
        let b = SynthDataset::new(DatasetSpec::Cifar10, 7);
        assert_eq!(a.protos, b.protos);
        let c = SynthDataset::new(DatasetSpec::Cifar10, 8);
        assert_ne!(a.protos, c.protos);
    }

    #[test]
    fn class_skew_biases_labels() {
        let ds = SynthDataset::new(DatasetSpec::Cifar10, 1);
        let mut rng = Rng::new(9);
        let s = ds.sample(&mut rng, 4000, 0.9);
        let mut counts = [0usize; 10];
        for &y in &s.ys {
            counts[y as usize] += 1;
        }
        let mut sorted = counts;
        sorted.sort_unstable();
        // Top-5 classes should hold well over half the data.
        let top5: usize = sorted[5..].iter().sum();
        assert!(top5 > 2800, "top5={top5}");
    }

    #[test]
    fn spec_roundtrip() {
        for s in [DatasetSpec::Fmnist, DatasetSpec::Cifar10, DatasetSpec::Caltech101] {
            assert_eq!(DatasetSpec::from_name(s.name()), Some(s));
        }
    }
}
