//! Pure-Rust reference trainer: a small conv net with hand-written
//! backprop. Produces *real* gradients with zero PJRT/artifact
//! dependencies — used by the motivation benches (Fig. 3/4/5: gradient
//! temporal structure under SGD and full-batch GD), by tests, and as a
//! fallback trainer when artifacts are absent.
//!
//! Architecture (for 32×32×3 inputs, C classes):
//!   conv3×3(3→8, SAME) → relu → avgpool4 (8×8×8) → dense(512→C) → softmax
//!
//! Deliberately compact: this is a substrate for generating authentic
//! gradient statistics, not a performance model. The HLO trainer
//! (`runtime::trainer`) is the production path.

use crate::tensor::{LayerGrad, LayerMeta, ModelGrad};
use crate::train::data::{DataSlice, IMG};
use crate::util::rng::Rng;

const C_IN: usize = 3;
const C_OUT: usize = 8;
const K: usize = 3;
const H: usize = 32;
const W: usize = 32;
const POOL: usize = 4;
const PH: usize = H / POOL;
const PW: usize = W / POOL;
const FEAT: usize = PH * PW * C_OUT;

/// Model parameters.
#[derive(Debug, Clone)]
pub struct NativeNet {
    pub classes: usize,
    /// Conv weight `[C_OUT, C_IN, K, K]`.
    pub conv_w: Vec<f32>,
    pub conv_b: Vec<f32>,
    /// Dense `[classes, FEAT]`.
    pub fc_w: Vec<f32>,
    pub fc_b: Vec<f32>,
}

/// Gradients in the same layout.
pub struct NativeGrads {
    pub conv_w: Vec<f32>,
    pub conv_b: Vec<f32>,
    pub fc_w: Vec<f32>,
    pub fc_b: Vec<f32>,
}

impl NativeNet {
    pub fn new(classes: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x4A71E);
        let conv_std = (2.0 / (C_IN * K * K) as f64).sqrt() as f32;
        let fc_std = (1.0 / FEAT as f64).sqrt() as f32;
        NativeNet {
            classes,
            conv_w: (0..C_OUT * C_IN * K * K).map(|_| rng.normal_f32(0.0, conv_std)).collect(),
            conv_b: vec![0.0; C_OUT],
            fc_w: (0..classes * FEAT).map(|_| rng.normal_f32(0.0, fc_std)).collect(),
            fc_b: vec![0.0; classes],
        }
    }

    /// Layer metadata for the compressor.
    pub fn layer_metas(&self) -> Vec<LayerMeta> {
        vec![
            LayerMeta::conv("conv", C_OUT, C_IN, K, K),
            LayerMeta::other("conv.bias", C_OUT),
            LayerMeta::dense("fc", self.classes, FEAT),
            LayerMeta::other("fc.bias", self.classes),
        ]
    }

    /// Forward + backward over a batch; returns (mean loss, accuracy,
    /// gradients).
    pub fn grad_batch(&self, batch: &DataSlice) -> (f32, f32, NativeGrads) {
        let n = batch.n;
        let img_len = IMG.iter().product::<usize>();
        let mut g = NativeGrads {
            conv_w: vec![0.0; self.conv_w.len()],
            conv_b: vec![0.0; self.conv_b.len()],
            fc_w: vec![0.0; self.fc_w.len()],
            fc_b: vec![0.0; self.fc_b.len()],
        };
        let mut total_loss = 0.0f64;
        let mut correct = 0usize;
        // Per-sample buffers reused across the batch.
        let mut conv_out = vec![0.0f32; C_OUT * H * W];
        let mut pooled = vec![0.0f32; FEAT];
        let mut logits = vec![0.0f32; self.classes];
        let mut dpool = vec![0.0f32; FEAT];
        let mut dconv = vec![0.0f32; C_OUT * H * W];
        for s in 0..n {
            let x = &batch.xs[s * img_len..(s + 1) * img_len]; // [H,W,C_IN]
            let y = batch.ys[s] as usize;
            // --- conv3x3 SAME + relu ---
            for co in 0..C_OUT {
                for i in 0..H {
                    for j in 0..W {
                        let mut acc = self.conv_b[co];
                        for ci in 0..C_IN {
                            for di in 0..K {
                                for dj in 0..K {
                                    let ii = i + di;
                                    let jj = j + dj;
                                    if ii >= 1 && jj >= 1 && ii - 1 < H && jj - 1 < W {
                                        let px = x[((ii - 1) * W + (jj - 1)) * C_IN + ci];
                                        acc += px
                                            * self.conv_w[((co * C_IN + ci) * K + di) * K + dj];
                                    }
                                }
                            }
                        }
                        conv_out[(co * H + i) * W + j] = acc.max(0.0);
                    }
                }
            }
            // --- avg pool 4x4 ---
            for co in 0..C_OUT {
                for pi in 0..PH {
                    for pj in 0..PW {
                        let mut acc = 0.0f32;
                        for di in 0..POOL {
                            for dj in 0..POOL {
                                acc += conv_out[(co * H + pi * POOL + di) * W + pj * POOL + dj];
                            }
                        }
                        pooled[(pi * PW + pj) * C_OUT + co] = acc / (POOL * POOL) as f32;
                    }
                }
            }
            // --- dense + softmax CE ---
            for c in 0..self.classes {
                let row = &self.fc_w[c * FEAT..(c + 1) * FEAT];
                let mut acc = self.fc_b[c];
                for (f, &p) in row.iter().zip(&pooled) {
                    acc += f * p;
                }
                logits[c] = acc;
            }
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for l in logits.iter() {
                denom += (l - max).exp();
            }
            let logz = max + denom.ln();
            total_loss += (logz - logits[y]) as f64;
            let argmax =
                logits.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
            if argmax == y {
                correct += 1;
            }
            // dlogits = softmax - onehot
            for c in 0..self.classes {
                let p = (logits[c] - logz).exp();
                let d = (p - if c == y { 1.0 } else { 0.0 }) / n as f32;
                g.fc_b[c] += d;
                let grow = &mut g.fc_w[c * FEAT..(c + 1) * FEAT];
                for (gw, &pv) in grow.iter_mut().zip(&pooled) {
                    *gw += d * pv;
                }
                logits[c] = d; // reuse as dlogits
            }
            // dpooled = fc_w^T dlogits
            dpool.fill(0.0);
            for c in 0..self.classes {
                let d = logits[c];
                let row = &self.fc_w[c * FEAT..(c + 1) * FEAT];
                for (dp, &f) in dpool.iter_mut().zip(row) {
                    *dp += d * f;
                }
            }
            // back through avg pool + relu
            dconv.fill(0.0);
            let inv = 1.0 / (POOL * POOL) as f32;
            for co in 0..C_OUT {
                for pi in 0..PH {
                    for pj in 0..PW {
                        let d = dpool[(pi * PW + pj) * C_OUT + co] * inv;
                        for di in 0..POOL {
                            for dj in 0..POOL {
                                let idx = (co * H + pi * POOL + di) * W + pj * POOL + dj;
                                if conv_out[idx] > 0.0 {
                                    dconv[idx] = d;
                                }
                            }
                        }
                    }
                }
            }
            // conv weight grads
            for co in 0..C_OUT {
                let mut db = 0.0f32;
                for i in 0..H {
                    for j in 0..W {
                        let d = dconv[(co * H + i) * W + j];
                        if d == 0.0 {
                            continue;
                        }
                        db += d;
                        for ci in 0..C_IN {
                            for di in 0..K {
                                for dj in 0..K {
                                    let ii = i + di;
                                    let jj = j + dj;
                                    if ii >= 1 && jj >= 1 && ii - 1 < H && jj - 1 < W {
                                        let px = x[((ii - 1) * W + (jj - 1)) * C_IN + ci];
                                        g.conv_w[((co * C_IN + ci) * K + di) * K + dj] += d * px;
                                    }
                                }
                            }
                        }
                    }
                }
                g.conv_b[co] += db;
            }
        }
        (total_loss as f32 / n as f32, correct as f32 / n as f32, g)
    }

    /// Apply an SGD step.
    pub fn apply(&mut self, g: &NativeGrads, lr: f32) {
        for (w, d) in self.conv_w.iter_mut().zip(&g.conv_w) {
            *w -= lr * d;
        }
        for (w, d) in self.conv_b.iter_mut().zip(&g.conv_b) {
            *w -= lr * d;
        }
        for (w, d) in self.fc_w.iter_mut().zip(&g.fc_w) {
            *w -= lr * d;
        }
        for (w, d) in self.fc_b.iter_mut().zip(&g.fc_b) {
            *w -= lr * d;
        }
    }

    /// Package gradients as a ModelGrad for the compressor.
    pub fn grads_to_model(&self, g: &NativeGrads) -> ModelGrad {
        let metas = self.layer_metas();
        ModelGrad {
            layers: vec![
                LayerGrad::new(metas[0].clone(), g.conv_w.clone()),
                LayerGrad::new(metas[1].clone(), g.conv_b.clone()),
                LayerGrad::new(metas[2].clone(), g.fc_w.clone()),
                LayerGrad::new(metas[3].clone(), g.fc_b.clone()),
            ],
        }
    }

    /// Overwrite parameters from a (reconstructed) ModelGrad-shaped delta:
    /// `θ ← θ − lr·g` per layer.
    pub fn apply_model_grad(&mut self, g: &ModelGrad, lr: f32) {
        let parts: [&mut Vec<f32>; 4] =
            [&mut self.conv_w, &mut self.conv_b, &mut self.fc_w, &mut self.fc_b];
        for (dst, layer) in parts.into_iter().zip(&g.layers) {
            for (w, d) in dst.iter_mut().zip(&layer.data) {
                *w -= lr * d;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::data::{DatasetSpec, SynthDataset};

    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Rng::new(1);
        let ds = SynthDataset::new(DatasetSpec::Cifar10, 1);
        let batch = ds.sample(&mut rng, 2, 0.0);
        let net = NativeNet::new(10, 2);
        let (_, _, g) = net.grad_batch(&batch);
        // Check a few weights in each tensor with central differences.
        let eps = 1e-3f32;
        let mut check = |get: &dyn Fn(&NativeNet) -> &Vec<f32>,
                         set: &dyn Fn(&mut NativeNet, usize, f32),
                         grad: &Vec<f32>,
                         idx: usize| {
            let mut p = net.clone();
            let w0 = get(&p)[idx];
            set(&mut p, idx, w0 + eps);
            let (lp, _, _) = p.grad_batch(&batch);
            set(&mut p, idx, w0 - eps);
            let (lm, _, _) = p.grad_batch(&batch);
            let fd = (lp - lm) / (2.0 * eps);
            let an = grad[idx];
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                "idx {idx}: fd {fd} vs analytic {an}"
            );
        };
        for idx in [0usize, 17, 100] {
            check(&|n| &n.conv_w, &|n, i, v| n.conv_w[i] = v, &g.conv_w, idx);
        }
        for idx in [0usize, 333] {
            check(&|n| &n.fc_w, &|n, i, v| n.fc_w[i] = v, &g.fc_w, idx);
        }
    }

    #[test]
    fn training_reduces_loss_and_beats_chance() {
        let mut rng = Rng::new(3);
        let ds = SynthDataset::new(DatasetSpec::Cifar10, 7);
        let train = ds.sample(&mut rng, 64, 0.0);
        let mut net = NativeNet::new(10, 4);
        let (first_loss, _, _) = net.grad_batch(&train);
        let mut last = (0.0, 0.0);
        for _ in 0..30 {
            let (loss, acc, g) = net.grad_batch(&train);
            net.apply(&g, 0.5);
            last = (loss, acc);
        }
        assert!(last.0 < first_loss * 0.8, "loss {first_loss} -> {}", last.0);
        assert!(last.1 > 0.3, "acc {}", last.1);
    }

    #[test]
    fn grads_to_model_layout() {
        let mut rng = Rng::new(5);
        let ds = SynthDataset::new(DatasetSpec::Fmnist, 1);
        let batch = ds.sample(&mut rng, 4, 0.0);
        let net = NativeNet::new(10, 6);
        let (_, _, g) = net.grad_batch(&batch);
        let mg = net.grads_to_model(&g);
        assert_eq!(mg.layers.len(), 4);
        assert_eq!(mg.layers[0].data.len(), C_OUT * C_IN * K * K);
        assert!(mg.layers[0].kernels().is_some());
    }
}
