//! Training substrates: synthetic datasets shaped like the paper's
//! (Fashion-MNIST / CIFAR-10 / Caltech101), a pure-Rust reference trainer
//! (real gradients with no PJRT dependency), and the calibrated
//! full-scale gradient-sequence generator used where CPU training of
//! ResNet-18/34-scale models is infeasible (DESIGN.md §5).

pub mod data;
pub mod gradgen;
pub mod native;
