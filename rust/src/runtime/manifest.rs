//! Reader for `artifacts/manifest.json` — the shape contract emitted by
//! `python/compile/aot.py` so the Rust side hard-codes no protocol.

use crate::util::json::Json;
use std::path::Path;

/// One trainable model's artifacts.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub key: String,
    pub train_file: String,
    pub eval_file: String,
    pub layer_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub num_classes: usize,
}

/// One predict_quantize kernel artifact.
#[derive(Debug, Clone)]
pub struct KernelArtifact {
    pub file: String,
    pub n: usize,
    pub tile: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub batches_per_epoch: usize,
    pub batch_size: usize,
    pub eval_n: usize,
    pub img: [usize; 3],
    pub models: Vec<ModelArtifacts>,
    pub kernels: Vec<KernelArtifact>,
}

impl Manifest {
    pub fn load(art_dir: impl AsRef<Path>) -> crate::Result<Manifest> {
        let path = art_dir.as_ref().join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {path:?}: {e} (run `make artifacts`)"))?;
        Self::parse(&src)
    }

    pub fn parse(src: &str) -> crate::Result<Manifest> {
        let v = Json::parse(src)?;
        let img_arr = v.get("img").and_then(Json::as_arr).unwrap_or(&[]);
        let mut img = [32usize, 32, 3];
        for (i, x) in img_arr.iter().take(3).enumerate() {
            img[i] = x.as_usize().unwrap_or(img[i]);
        }
        let mut models = Vec::new();
        if let Some(obj) = v.get("models").and_then(Json::as_obj) {
            for (key, m) in obj {
                let layer_names = m
                    .get("layer_names")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                    .unwrap_or_default();
                let param_shapes = m
                    .get("param_shapes")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .map(|shape| {
                                shape
                                    .as_arr()
                                    .map(|dims| {
                                        dims.iter().filter_map(Json::as_usize).collect::<Vec<_>>()
                                    })
                                    .unwrap_or_default()
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                models.push(ModelArtifacts {
                    key: key.clone(),
                    train_file: m.str_or("train", "").to_string(),
                    eval_file: m.str_or("eval", "").to_string(),
                    layer_names,
                    param_shapes,
                    num_classes: m.usize_or("num_classes", 10),
                });
            }
        }
        let mut kernels = Vec::new();
        if let Some(obj) = v.get("kernels").and_then(Json::as_obj) {
            for (_, k) in obj {
                kernels.push(KernelArtifact {
                    file: k.str_or("file", "").to_string(),
                    n: k.usize_or("n", 0),
                    tile: k.usize_or("tile", 0),
                });
            }
        }
        kernels.sort_by_key(|k| k.n);
        Ok(Manifest {
            batches_per_epoch: v.usize_or("batches_per_epoch", 8),
            batch_size: v.usize_or("batch_size", 32),
            eval_n: v.usize_or("eval_n", 256),
            img,
            models,
            kernels,
        })
    }

    /// Find a model's artifacts by key (`micro_resnet_c10`…).
    pub fn model(&self, key: &str) -> Option<&ModelArtifacts> {
        self.models.iter().find(|m| m.key == key)
    }

    /// Largest kernel with `n <= cap`, or the smallest one.
    pub fn kernel_for(&self, numel: usize) -> Option<&KernelArtifact> {
        self.kernels.iter().rev().find(|k| k.n <= numel.max(1)).or_else(|| self.kernels.first())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "batch_size": 32, "batches_per_epoch": 8, "eval_n": 256,
        "img": [32, 32, 3],
        "kernels": {"4096": {"file": "pq4096.hlo.txt", "n": 4096, "tile": 4096}},
        "models": {"micro_resnet_c10": {
            "train": "t.hlo.txt", "eval": "e.hlo.txt",
            "layer_names": ["stem.conv", "stem.bias"],
            "param_shapes": [[16, 3, 3, 3], [16]],
            "num_classes": 10}}
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch_size, 32);
        assert_eq!(m.img, [32, 32, 3]);
        let model = m.model("micro_resnet_c10").unwrap();
        assert_eq!(model.layer_names.len(), 2);
        assert_eq!(model.param_shapes[0], vec![16, 3, 3, 3]);
        assert_eq!(m.kernels.len(), 1);
        assert_eq!(m.kernel_for(100_000).unwrap().n, 4096);
    }

    #[test]
    fn missing_model_is_none() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.model("nope").is_none());
    }

    /// The real manifest (when artifacts exist) parses and is coherent
    /// with the Rust model zoo.
    #[test]
    fn real_manifest_if_present() {
        let dir = crate::runtime::Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.kernels.is_empty());
        for model in &m.models {
            assert!(!model.layer_names.is_empty());
            assert_eq!(model.layer_names.len(), model.param_shapes.len());
        }
    }
}
