//! The HLO trainer: runs the JAX micro-CNN `train_epoch` / `eval` graphs
//! from Rust. Parameters live in Rust as flat f32 vectors (ordered per the
//! manifest's `layer_names`); every round the coordinator feeds them
//! through PJRT and receives the updated parameters + loss back.

use std::cell::RefCell;
use std::rc::Rc;

use super::manifest::{Manifest, ModelArtifacts};
use super::{literal_f32, literal_i32, to_f32_scalar, to_f32s, Runtime};

/// Model parameters as flat vectors, ordered per manifest.
#[derive(Debug, Clone, Default)]
pub struct Params {
    pub tensors: Vec<Vec<f32>>,
}

impl Params {
    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }
}

/// PJRT-backed trainer for one model artifact.
pub struct HloTrainer {
    rt: Rc<RefCell<Runtime>>,
    pub model: ModelArtifacts,
    pub manifest: Manifest,
}

impl HloTrainer {
    pub fn new(rt: Rc<RefCell<Runtime>>, manifest: &Manifest, key: &str) -> crate::Result<Self> {
        let model = manifest
            .model(key)
            .ok_or_else(|| anyhow::anyhow!("model {key} not in manifest"))?
            .clone();
        {
            let mut r = rt.borrow_mut();
            r.load(&model.train_file)?;
            r.load(&model.eval_file)?;
        }
        Ok(HloTrainer { rt, model, manifest: manifest.clone() })
    }

    /// Deterministic He-style initialization matching model.py's scheme
    /// (same structure; exact values come from the Rust RNG so the whole
    /// FL run is reproducible from one seed without Python).
    pub fn init_params(&self, seed: u64) -> Params {
        let mut rng = crate::util::rng::Rng::new(seed);
        let tensors = self
            .model
            .param_shapes
            .iter()
            .map(|shape| {
                let numel: usize = shape.iter().product();
                if shape.len() == 1 {
                    vec![0.0; numel] // biases start at zero
                } else {
                    let fan_in: usize =
                        if shape.len() == 4 { shape[1] * shape[2] * shape[3] } else { shape[1] };
                    let std = (2.0 / fan_in as f64).sqrt() as f32;
                    (0..numel).map(|_| rng.normal_f32(0.0, std)).collect()
                }
            })
            .collect();
        Params { tensors }
    }

    fn param_literals(&self, params: &Params) -> crate::Result<Vec<xla::Literal>> {
        params
            .tensors
            .iter()
            .zip(&self.model.param_shapes)
            .map(|(t, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                literal_f32(t, &dims)
            })
            .collect()
    }

    /// One local epoch of minibatch SGD inside XLA.
    ///
    /// `xs`: flat `[nb*bs*H*W*C]` images, `ys`: `[nb*bs]` labels.
    /// Returns (new params, mean loss).
    pub fn train_epoch(
        &self,
        params: &Params,
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> crate::Result<(Params, f32)> {
        let m = &self.manifest;
        let (nb, bs) = (m.batches_per_epoch, m.batch_size);
        let [h, w, c] = m.img;
        anyhow::ensure!(xs.len() == nb * bs * h * w * c, "xs len {}", xs.len());
        anyhow::ensure!(ys.len() == nb * bs, "ys len {}", ys.len());
        let mut inputs = self.param_literals(params)?;
        inputs.push(literal_f32(xs, &[nb as i64, bs as i64, h as i64, w as i64, c as i64])?);
        inputs.push(literal_i32(ys, &[nb as i64, bs as i64])?);
        inputs.push(xla::Literal::scalar(lr));
        let out = self.rt.borrow().exec(&self.model.train_file, &inputs)?;
        let n_params = self.model.param_shapes.len();
        anyhow::ensure!(out.len() == n_params + 1, "train returned {} outputs", out.len());
        let mut tensors = Vec::with_capacity(n_params);
        for lit in &out[..n_params] {
            tensors.push(to_f32s(lit)?);
        }
        let loss = to_f32_scalar(&out[n_params])?;
        Ok((Params { tensors }, loss))
    }

    /// Evaluate on a fixed-size batch; returns (mean loss, accuracy).
    pub fn eval(&self, params: &Params, xs: &[f32], ys: &[i32]) -> crate::Result<(f32, f32)> {
        let m = &self.manifest;
        let [h, w, c] = m.img;
        let n = m.eval_n;
        anyhow::ensure!(xs.len() == n * h * w * c && ys.len() == n, "eval shapes");
        let mut inputs = self.param_literals(params)?;
        inputs.push(literal_f32(xs, &[n as i64, h as i64, w as i64, c as i64])?);
        inputs.push(literal_i32(ys, &[n as i64])?);
        let out = self.rt.borrow().exec(&self.model.eval_file, &inputs)?;
        anyhow::ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        let loss = to_f32_scalar(&out[0])?;
        let correct = to_f32_scalar(&out[1])?;
        Ok((loss, correct / n as f32))
    }

    /// Layer metadata for the compressor, derived from manifest names +
    /// shapes (conv = rank 4, dense = rank 2, else other).
    pub fn layer_metas(&self) -> Vec<crate::tensor::LayerMeta> {
        self.model
            .layer_names
            .iter()
            .zip(&self.model.param_shapes)
            .map(|(name, shape)| match shape.len() {
                4 => crate::tensor::LayerMeta::conv(name, shape[0], shape[1], shape[2], shape[3]),
                2 => crate::tensor::LayerMeta::dense(name, shape[0], shape[1]),
                _ => crate::tensor::LayerMeta::other(name, shape.iter().product()),
            })
            .collect()
    }
}
