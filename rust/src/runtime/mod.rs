//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via
//! the `xla` crate. This is the only place Python-born code runs — and it
//! runs as compiled XLA, never as Python (DESIGN.md §1).
//!
//! Pattern from /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.

pub mod engine;
pub mod manifest;
pub mod trainer;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::Context;

/// A PJRT CPU client plus a cache of compiled executables keyed by
/// artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    art_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU runtime rooted at an artifacts directory.
    pub fn new(art_dir: impl AsRef<Path>) -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, exes: HashMap::new(), art_dir: art_dir.as_ref().to_path_buf() })
    }

    /// Default artifacts directory: `$FEDGEC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FEDGEC_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached by file name).
    pub fn load(&mut self, file: &str) -> crate::Result<()> {
        if self.exes.contains_key(file) {
            return Ok(());
        }
        let path = self.art_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse HLO text {path:?} (run `make artifacts`?)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {file}"))?;
        self.exes.insert(file.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact. The AOT side lowers with
    /// `return_tuple=True`, so the single output literal is decomposed
    /// into the tuple elements.
    pub fn exec(&self, file: &str, inputs: &[xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(file)
            .ok_or_else(|| anyhow::anyhow!("artifact {file} not loaded"))?;
        let result =
            exe.execute::<xla::Literal>(inputs).with_context(|| format!("execute {file}"))?;
        let out = result[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }

    /// Is an artifact file present on disk (without loading it)?
    pub fn has_artifact(&self, file: &str) -> bool {
        self.art_dir.join(file).exists()
    }

    pub fn art_dir(&self) -> &Path {
        &self.art_dir
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> crate::Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 && dims[0] as usize == data.len() {
        return Ok(lit);
    }
    Ok(lit.reshape(dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> crate::Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 && dims[0] as usize == data.len() {
        return Ok(lit);
    }
    Ok(lit.reshape(dims)?)
}

/// Extract an f32 vector from a literal.
pub fn to_f32s(lit: &xla::Literal) -> crate::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn to_f32_scalar(lit: &xla::Literal) -> crate::Result<f32> {
    let v = lit.to_vec::<f32>()?;
    v.first().copied().ok_or_else(|| anyhow::anyhow!("empty literal"))
}
