//! The HLO predict engine: runs the Pallas `predict_quantize` kernel's
//! lowering through PJRT as the codec's predict stage
//! ([`crate::compress::pipeline::PredictBackend`]).
//!
//! Layers are processed in fixed-size blocks matching the AOT kernel
//! shape; the tail block is zero-padded and the pad lanes discarded.
//! σ_prev is floored to `SIGMA_EPS` on the Rust side exactly as the kernel
//! does internally, so padding cannot produce NaNs.

use std::cell::RefCell;
use std::rc::Rc;

use crate::compress::fused::FusedParams;
use crate::compress::pipeline::PredictBackend;
use crate::runtime::{literal_f32, to_f32s, Runtime};

/// Predict-stage engine backed by a PJRT-compiled Pallas kernel.
pub struct HloPredictEngine {
    rt: Rc<RefCell<Runtime>>,
    file: String,
    block: usize,
}

impl HloPredictEngine {
    /// Load the kernel artifact for block size `block` (e.g. 4096/65536).
    pub fn new(rt: Rc<RefCell<Runtime>>, block: usize) -> crate::Result<Self> {
        let file = format!("predict_quantize_{block}.hlo.txt");
        rt.borrow_mut().load(&file)?;
        Ok(HloPredictEngine { rt, file, block })
    }

    fn run_block(
        &self,
        prev_abs: &[f32],
        memory: &[f32],
        signs: &[f32],
        grad_zeros: &[f32],
        p: &FusedParams,
    ) -> crate::Result<(Vec<f32>, Vec<f32>)> {
        let scalars = [
            p.beta,
            p.mu_curr,
            p.sigma_curr,
            p.mu_prev,
            p.sigma_prev,
            // The kernel divides by two_delta; it is never called with 0
            // (the pipeline escapes whole layers with degenerate deltas),
            // but guard anyway so padding paths stay finite.
            if p.two_delta > 0.0 { p.two_delta } else { 1.0 },
            0.0,
            0.0,
        ];
        let n = self.block as i64;
        let inputs = [
            literal_f32(prev_abs, &[n])?,
            literal_f32(memory, &[n])?,
            literal_f32(signs, &[n])?,
            literal_f32(grad_zeros, &[n])?,
            literal_f32(&scalars, &[8])?,
        ];
        let rt = self.rt.borrow();
        let out = rt.exec(&self.file, &inputs)?;
        if out.len() != 3 {
            anyhow::bail!("kernel returned {} outputs, expected 3", out.len());
        }
        // outputs: (codes, ghat, new_memory); codes unused here — the
        // pipeline quantizes against ghat so escape handling is shared
        // with the native path.
        let ghat = to_f32s(&out[1])?;
        let new_mem = to_f32s(&out[2])?;
        Ok((ghat, new_mem))
    }
}

// `Rc<RefCell<Runtime>>` is not Send; the engine is only used from the
// thread that owns the runtime. The PredictBackend trait requires Send for
// the multi-threaded native pipelines, so we assert single-thread use here.
// Safety: HloPredictEngine instances are created, used and dropped on one
// thread (the coordinator's); the FL runtime never moves codecs with HLO
// engines across threads (enforced by `Coordinator::new_hlo`).
unsafe impl Send for HloPredictEngine {}

impl PredictBackend for HloPredictEngine {
    fn predict(
        &mut self,
        prev_abs: &[f32],
        memory: &mut [f32],
        signs: &[f32],
        p: &FusedParams,
    ) -> anyhow::Result<Vec<f32>> {
        let n = prev_abs.len();
        if memory.len() != n || signs.len() != n {
            anyhow::bail!("engine: length mismatch");
        }
        let b = self.block;
        let zeros = vec![0.0f32; b];
        let mut ghat = Vec::with_capacity(n);
        let mut start = 0usize;
        let mut pa = vec![0.0f32; b];
        let mut me = vec![0.0f32; b];
        let mut sg = vec![0.0f32; b];
        while start < n {
            let len = (n - start).min(b);
            if len == b {
                let (g, m) = self.run_block(
                    &prev_abs[start..start + b],
                    &memory[start..start + b],
                    &signs[start..start + b],
                    &zeros,
                    p,
                )?;
                memory[start..start + b].copy_from_slice(&m);
                ghat.extend_from_slice(&g);
            } else {
                pa[..len].copy_from_slice(&prev_abs[start..start + len]);
                pa[len..].fill(0.0);
                me[..len].copy_from_slice(&memory[start..start + len]);
                me[len..].fill(0.0);
                sg[..len].copy_from_slice(&signs[start..start + len]);
                sg[len..].fill(0.0);
                let (g, m) = self.run_block(&pa, &me, &sg, &zeros, p)?;
                memory[start..start + len].copy_from_slice(&m[..len]);
                ghat.extend_from_slice(&g[..len]);
            }
            start += len;
        }
        Ok(ghat)
    }
}
